//! The `QCKP` checkpoint format shared with `python/compile/train.py`:
//!
//!   magic "QCKP" (u32 LE) | version u32 | config-json string |
//!   n_tensors u32 | { name string | ndim u32 | dims u64× | f32 data }×
//!
//! Tensors are row-major f32. Linear weights are stored (out_dim, in_dim).

use super::config::ModelConfig;
use crate::util::bytes::{Reader, Writer};
use crate::util::json::Json;
use std::collections::HashMap;

pub const CKPT_MAGIC: u32 = 0x504B_4351; // "QCKP" LE

/// A loaded checkpoint: config + named tensors.
pub struct Checkpoint {
    pub config: ModelConfig,
    pub tensors: HashMap<String, Tensor>,
}

#[derive(Clone, Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }
}

impl Checkpoint {
    pub fn load(path: &std::path::Path) -> crate::Result<Checkpoint> {
        let raw = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {path:?}: {e}"))?;
        let mut r = Reader::new(&raw);
        let magic = r.u32()?;
        anyhow::ensure!(magic == CKPT_MAGIC, "bad checkpoint magic {magic:#x}");
        let version = r.u32()?;
        anyhow::ensure!(version == 1, "unsupported checkpoint version {version}");
        let cfg_text = r.string()?;
        let config = ModelConfig::from_json(&Json::parse(&cfg_text)?)?;
        let n = r.u32()? as usize;
        let mut tensors = HashMap::with_capacity(n);
        for _ in 0..n {
            let name = r.string()?;
            let ndim = r.u32()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u64()? as usize);
            }
            let count: usize = dims.iter().product();
            let raw = r.bytes(count * 4)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.insert(name, Tensor { dims, data });
        }
        Ok(Checkpoint { config, tensors })
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut w = Writer::new();
        w.u32(CKPT_MAGIC);
        w.u32(1);
        w.string(&self.config.to_json().to_string());
        w.u32(self.tensors.len() as u32);
        // Sort names for a deterministic byte stream.
        let mut names: Vec<&String> = self.tensors.keys().collect();
        names.sort();
        for name in names {
            let t = &self.tensors[name];
            w.string(name);
            w.u32(t.dims.len() as u32);
            for &d in &t.dims {
                w.u64(d as u64);
            }
            for &x in &t.data {
                w.f32(x);
            }
        }
        crate::util::fsx::atomic_write(path, &w.buf)
    }

    pub fn tensor(&self, name: &str) -> crate::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))
    }

    /// A randomly-initialized checkpoint (tests and the quickstart use
    /// this when trained artifacts are absent).
    pub fn random(config: &ModelConfig, seed: u64) -> Checkpoint {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let d = config.d_model;
        let mut tensors = HashMap::new();
        let mut normal = |dims: Vec<usize>, scale: f64| {
            let n: usize = dims.iter().product();
            let data: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
            Tensor { dims, data }
        };
        tensors.insert("embed".into(), normal(vec![config.vocab, d], 0.02));
        tensors.insert("pos_embed".into(), normal(vec![config.max_seq, d], 0.02));
        for b in 0..config.n_layers {
            let s = 0.02 / (2.0 * config.n_layers as f64).sqrt();
            tensors.insert(format!("blk{b}.attn.wq"), normal(vec![d, d], 0.02));
            tensors.insert(format!("blk{b}.attn.wk"), normal(vec![d, d], 0.02));
            tensors.insert(format!("blk{b}.attn.wv"), normal(vec![d, d], 0.02));
            tensors.insert(format!("blk{b}.attn.wo"), normal(vec![d, d], s));
            tensors.insert(format!("blk{b}.mlp.w1"), normal(vec![config.d_ff, d], 0.02));
            tensors.insert(format!("blk{b}.mlp.w2"), normal(vec![d, config.d_ff], s));
            tensors.insert(format!("blk{b}.mlp.b1"), Tensor::new(vec![config.d_ff], vec![0.0; config.d_ff]));
            tensors.insert(format!("blk{b}.mlp.b2"), Tensor::new(vec![d], vec![0.0; d]));
            for ln in ["ln1", "ln2"] {
                tensors.insert(format!("blk{b}.{ln}.g"), Tensor::new(vec![d], vec![1.0; d]));
                tensors.insert(format!("blk{b}.{ln}.b"), Tensor::new(vec![d], vec![0.0; d]));
            }
        }
        tensors.insert("lnf.g".into(), Tensor::new(vec![d], vec![1.0; d]));
        tensors.insert("lnf.b".into(), Tensor::new(vec![d], vec![0.0; d]));
        Checkpoint {
            config: config.clone(),
            tensors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        let ck = Checkpoint::random(&cfg, 1);
        let dir = std::env::temp_dir().join("quip_ckpt_test");
        let path = dir.join("t.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.config, cfg);
        assert_eq!(back.tensors.len(), ck.tensors.len());
        let a = ck.tensor("blk0.attn.wq").unwrap();
        let b = back.tensor("blk0.attn.wq").unwrap();
        assert_eq!(a.dims, b.dims);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn random_has_all_linear_layers() {
        let cfg = ModelConfig::sized("t", 32, 3, 4, 64);
        let ck = Checkpoint::random(&cfg, 2);
        for spec in cfg.linear_specs() {
            let t = ck.tensor(&spec.name).unwrap();
            assert_eq!(t.dims, vec![spec.out_dim, spec.in_dim], "{}", spec.name);
        }
    }

    #[test]
    fn missing_tensor_is_error() {
        let cfg = ModelConfig::sized("t", 32, 1, 4, 64);
        let ck = Checkpoint::random(&cfg, 3);
        assert!(ck.tensor("nope").is_err());
    }
}
