//! Model configuration and the inventory of quantizable linear layers.

use crate::util::json::Json;

/// GPT-style decoder-only transformer configuration. Matches
/// `python/compile/model.py` field for field.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Total parameter count (embeddings + blocks + final LN; LM head is
    /// tied to the embedding).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_block = 4 * d * d       // wq wk wv wo
            + 2 * d * self.d_ff         // w1 w2
            + 4 * d                     // ln1/ln2 gain+bias
            + self.d_ff + d;            // b1 + b2 (mlp biases)
        self.vocab * d + self.max_seq * d + self.n_layers * per_block + 2 * d
    }

    /// The model-size series used across the experiments (stand-ins for
    /// the paper's OPT 125m…30b series; see DESIGN.md §2).
    pub fn series() -> Vec<ModelConfig> {
        vec![
            Self::sized("s0", 64, 2, 4, 256),
            Self::sized("s1", 128, 4, 4, 512),
            Self::sized("s2", 256, 6, 8, 1024),
            Self::sized("s3", 384, 8, 8, 1536),
        ]
    }

    pub fn sized(name: &str, d: usize, layers: usize, heads: usize, dff: usize) -> ModelConfig {
        ModelConfig {
            name: name.to_string(),
            d_model: d,
            n_layers: layers,
            n_heads: heads,
            d_ff: dff,
            vocab: 256,
            max_seq: 128,
        }
    }

    pub fn by_name(name: &str) -> crate::Result<ModelConfig> {
        Self::series()
            .into_iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (have s0..s3)"))
    }

    /// All quantizable linear layers, in forward order. `hkey` identifies
    /// the shared Hessian (q/k/v read the same activations).
    pub fn linear_specs(&self) -> Vec<LinearSpec> {
        let d = self.d_model;
        let mut out = Vec::new();
        for b in 0..self.n_layers {
            for w in ["wq", "wk", "wv"] {
                out.push(LinearSpec {
                    name: format!("blk{b}.attn.{w}"),
                    out_dim: d,
                    in_dim: d,
                    hkey: format!("blk{b}.attn.in"),
                });
            }
            out.push(LinearSpec {
                name: format!("blk{b}.attn.wo"),
                out_dim: d,
                in_dim: d,
                hkey: format!("blk{b}.attn.wo.in"),
            });
            out.push(LinearSpec {
                name: format!("blk{b}.mlp.w1"),
                out_dim: self.d_ff,
                in_dim: d,
                hkey: format!("blk{b}.mlp.w1.in"),
            });
            out.push(LinearSpec {
                name: format!("blk{b}.mlp.w2"),
                out_dim: d,
                in_dim: self.d_ff,
                hkey: format!("blk{b}.mlp.w2.in"),
            });
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("d_model", Json::Num(self.d_model as f64));
        j.set("n_layers", Json::Num(self.n_layers as f64));
        j.set("n_heads", Json::Num(self.n_heads as f64));
        j.set("d_ff", Json::Num(self.d_ff as f64));
        j.set("vocab", Json::Num(self.vocab as f64));
        j.set("max_seq", Json::Num(self.max_seq as f64));
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            d_ff: j.req_usize("d_ff")?,
            vocab: j.req_usize("vocab")?,
            max_seq: j.req_usize("max_seq")?,
        })
    }
}

/// One quantizable linear layer: y = W x, W of shape (out_dim, in_dim).
#[derive(Clone, Debug, PartialEq)]
pub struct LinearSpec {
    pub name: String,
    pub out_dim: usize,
    pub in_dim: usize,
    /// Hessian sharing key: layers with equal `hkey` see identical inputs.
    pub hkey: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_is_increasing_in_params() {
        let s = ModelConfig::series();
        for w in s.windows(2) {
            assert!(w[1].param_count() > w[0].param_count());
        }
        // ballpark sanity for the largest: ~10-20M params
        let p = s.last().unwrap().param_count();
        assert!((8_000_000..25_000_000).contains(&p), "params={p}");
    }

    #[test]
    fn linear_specs_count_and_sharing() {
        let cfg = ModelConfig::sized("t", 64, 3, 4, 256);
        let specs = cfg.linear_specs();
        assert_eq!(specs.len(), 3 * 6);
        // q/k/v share an hkey per block, others do not.
        let q = specs.iter().find(|s| s.name == "blk1.attn.wq").unwrap();
        let k = specs.iter().find(|s| s.name == "blk1.attn.wk").unwrap();
        let o = specs.iter().find(|s| s.name == "blk1.attn.wo").unwrap();
        assert_eq!(q.hkey, k.hkey);
        assert_ne!(q.hkey, o.hkey);
        // mlp dims
        let w1 = specs.iter().find(|s| s.name == "blk0.mlp.w1").unwrap();
        assert_eq!((w1.out_dim, w1.in_dim), (256, 64));
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ModelConfig::by_name("s1").unwrap();
        let j = cfg.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn unknown_model_errors() {
        assert!(ModelConfig::by_name("s9").is_err());
    }
}
