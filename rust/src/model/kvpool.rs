//! Paged KV-cache pool: fixed-size pages, per-sequence block tables,
//! ref-counted copy-on-write prefix sharing, and explicit exhaustion.
//!
//! The contiguous [`super::transformer::KvCacheContig`] allocates
//! `max_seq × d_model` floats per layer per sequence up front, so serving
//! memory is O(max_seq × sequences) even when most positions are empty.
//! The pool instead slices one backing allocation into fixed-size pages
//! of [`DEFAULT_PAGE_TOKENS`] token rows each; a sequence holds a
//! [`BlockTable`] mapping logical position `j` to page `j / page_tokens`,
//! slot `j % page_tokens`, and pages are handed out only as tokens are
//! actually written — KV memory is O(active tokens).
//!
//! # Layout
//!
//! Per transformer layer the pool owns one flat `pages × page_tokens × d`
//! K buffer and one V buffer; token row `j` of a sequence whose table
//! maps `j` to page `p` lives at `(p · page_tokens + j % page_tokens) · d`.
//! A page therefore spans the *same* page index in every layer — pages
//! are allocated and freed for all layers at once, which keeps the block
//! table per sequence rather than per (sequence, layer).
//!
//! # Sharing and copy-on-write
//!
//! Pages are ref-counted. A prefix registry maps a chain hash of the
//! first `p` prompt tokens to the page holding rows `⌊(p−1)/P⌋·P ..= p−1`;
//! admission ([`KvPool::try_admit`]) walks the registry to find the
//! longest registered prefix of a new prompt and builds a table that
//! references those pages directly (refcount bump, zero copies). Shared
//! pages are marked not-owned in the table; the first append into a
//! not-owned partial page copies the rows below the write slot into a
//! fresh page first (copy-on-write), so divergence never disturbs other
//! sequences. Full shared pages are never written again and are shared
//! for the sequence's whole lifetime.
//!
//! The registry itself holds one reference per registered page, so prompt
//! pages survive their owner sequence and act as a prefix cache. When an
//! allocation would fail, registry-only pages (refcount 1, keys present)
//! are evicted first; if none remain the pool reports exhaustion as an
//! explicit `Err` — never an OOM or a panic on the serving path (the
//! scheduler stalls or sheds the sequence instead).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Default token rows per page. 16 balances internal fragmentation
/// (≤ 15 wasted rows per sequence tail) against table/COW overhead.
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// Shared handle to a pool: the scheduler, every paged cache, and the
/// metrics snapshotter all hold one. Operations lock per call (the lock
/// guards table/refcount bookkeeping measured in nanoseconds; the matvec
/// work between calls dwarfs it).
pub type SharedKvPool = Arc<Mutex<KvPool>>;

/// Counters describing pool behavior since construction. Read under the
/// pool lock; [`KvPool::snapshot`] copies them out for the metrics layer.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Copy-on-write page copies triggered by diverging writes.
    pub cow_copies: u64,
    /// Admission attempts that consulted the prefix registry.
    pub prefix_lookups: u64,
    /// Admissions that shared at least one token of registered prefix.
    pub prefix_hits: u64,
    /// Total prompt tokens served from shared pages instead of recompute.
    pub prefix_tokens_shared: u64,
    /// Registry-only pages reclaimed under allocation pressure.
    pub evictions: u64,
    /// High-water mark of pages in use.
    pub peak_pages: usize,
}

/// Point-in-time copy of pool occupancy + stats for metrics export.
#[derive(Clone, Debug, Default)]
pub struct PoolSnapshot {
    pub pages_used: usize,
    pub pages_total: usize,
    pub peak_pages: usize,
    pub cow_copies: u64,
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_tokens_shared: u64,
    pub evictions: u64,
}

/// One sequence's mapping from logical token positions to pool pages.
/// `len` counts written rows; position `j < len` lives in
/// `pages[j / page_tokens]`. `owned[i]` is false while page `i` is a
/// shared prefix page this sequence must copy before writing into.
#[derive(Debug, Default)]
pub struct BlockTable {
    pages: Vec<u32>,
    owned: Vec<bool>,
    len: usize,
    /// Prefix registrations to fire as prefill crosses each length:
    /// `(at_len, chain_hash)`, ascending. Computed at admission (the
    /// prompt is known); fired by [`KvPool::advance`].
    pending: Vec<(usize, u64)>,
}

impl BlockTable {
    /// An empty table (no shared prefix, no pending registrations).
    pub fn new() -> BlockTable {
        BlockTable::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently referenced by this table.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }
}

/// FNV-1a chain over tokens: `hashes[p]` identifies the prefix
/// `tokens[..p]` (position-dependent via chaining). 64-bit; collisions
/// are astronomically unlikely at serving scale and at worst share a
/// wrong prefix whose logits diverge — acceptable for a cache key.
pub fn prefix_hashes(tokens: &[u32]) -> Vec<u64> {
    let mut hs = Vec::with_capacity(tokens.len() + 1);
    let mut h = 0xcbf29ce484222325u64;
    hs.push(h);
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
        hs.push(h);
    }
    hs
}

struct LayerStore {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// The fixed-size page pool backing every paged KV cache of one server.
pub struct KvPool {
    n_layers: usize,
    d: usize,
    page_tokens: usize,
    n_pages: usize,
    layers: Vec<LayerStore>,
    refcnt: Vec<u32>,
    free: Vec<u32>,
    /// chain hash of a prompt prefix → page holding its tail rows.
    registry: HashMap<u64, u32>,
    /// page → registry keys pointing at it (registry holds one refcount
    /// per page with ≥1 key; eviction removes a page's keys together).
    page_keys: Vec<Vec<u64>>,
    pub stats: PoolStats,
}

impl KvPool {
    pub fn new(n_layers: usize, d: usize, n_pages: usize, page_tokens: usize) -> KvPool {
        let (n_pages, page_tokens) = (n_pages.max(1), page_tokens.max(1));
        KvPool {
            n_layers,
            d,
            page_tokens,
            n_pages,
            layers: (0..n_layers)
                .map(|_| LayerStore {
                    k: vec![0.0; n_pages * page_tokens * d],
                    v: vec![0.0; n_pages * page_tokens * d],
                })
                .collect(),
            refcnt: vec![0; n_pages],
            free: (0..n_pages as u32).rev().collect(),
            registry: HashMap::new(),
            page_keys: vec![Vec::new(); n_pages],
            stats: PoolStats::default(),
        }
    }

    pub fn shared(n_layers: usize, d: usize, n_pages: usize, page_tokens: usize) -> SharedKvPool {
        Arc::new(Mutex::new(KvPool::new(n_layers, d, n_pages, page_tokens)))
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn capacity(&self) -> usize {
        self.n_pages
    }

    pub fn pages_in_use(&self) -> usize {
        self.n_pages - self.free.len()
    }

    /// Bytes of K+V storage one page spans across all layers.
    pub fn page_bytes(&self) -> usize {
        self.n_layers * 2 * self.page_tokens * self.d * std::mem::size_of::<f32>()
    }

    pub fn bytes_in_use(&self) -> usize {
        self.pages_in_use() * self.page_bytes()
    }

    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            pages_used: self.pages_in_use(),
            pages_total: self.n_pages,
            peak_pages: self.stats.peak_pages,
            cow_copies: self.stats.cow_copies,
            prefix_lookups: self.stats.prefix_lookups,
            prefix_hits: self.stats.prefix_hits,
            prefix_tokens_shared: self.stats.prefix_tokens_shared,
            evictions: self.stats.evictions,
        }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Pages whose only reference is the prefix registry (reclaimable).
    fn evictable_pages(&self) -> usize {
        (0..self.n_pages)
            .filter(|&p| self.refcnt[p] == 1 && !self.page_keys[p].is_empty())
            .count()
    }

    fn evict_registry(&mut self) -> usize {
        let mut freed = 0;
        for p in 0..self.n_pages {
            if self.refcnt[p] == 1 && !self.page_keys[p].is_empty() {
                for key in self.page_keys[p].drain(..) {
                    self.registry.remove(&key);
                }
                self.refcnt[p] = 0;
                self.free.push(p as u32);
                freed += 1;
            }
        }
        self.stats.evictions += freed as u64;
        freed
    }

    fn alloc_page(&mut self) -> crate::Result<u32> {
        if self.free.is_empty() {
            self.evict_registry();
        }
        match self.free.pop() {
            Some(p) => {
                self.refcnt[p as usize] = 1;
                self.stats.peak_pages = self.stats.peak_pages.max(self.pages_in_use());
                Ok(p)
            }
            None => anyhow::bail!(
                "kv pool exhausted: all {} pages ({} tokens) in use",
                self.n_pages,
                self.n_pages * self.page_tokens
            ),
        }
    }

    /// Walk the prefix registry for the longest registered prefix of
    /// `prompt` that leaves at least the final token to recompute (the
    /// admitted sequence needs fresh logits to sample from). Returns the
    /// shared length and the pages covering it, without mutating anything.
    fn lookup_prefix(&self, prompt: &[u32]) -> (usize, Vec<u32>) {
        let pt = self.page_tokens;
        let max_share = prompt.len().saturating_sub(1);
        let hs = prefix_hashes(&prompt[..max_share]);
        let mut pages = Vec::new();
        let mut shared = 0usize;
        // Full pages first: each has its own boundary key.
        let mut k = 1usize;
        while k * pt <= max_share {
            match self.registry.get(&hs[k * pt]) {
                Some(&pg) => {
                    pages.push(pg);
                    shared = k * pt;
                    k += 1;
                }
                None => break,
            }
        }
        // Then the longest registered tail into the next page.
        let hi = max_share.min(shared + pt - 1);
        let mut p = hi;
        while p > shared {
            if let Some(&pg) = self.registry.get(&hs[p]) {
                pages.push(pg);
                shared = p;
                break;
            }
            p -= 1;
        }
        (shared, pages)
    }

    /// Admission control: build a block table for `prompt` if the pool
    /// can cover the prompt plus `reserve` generated tokens (counting
    /// reclaimable registry pages), sharing the longest registered
    /// prefix. Returns `None` — with **no** state mutated — when the
    /// reservation does not fit; the caller queues or sheds the request.
    pub fn try_admit(&mut self, prompt: &[u32], reserve: usize) -> Option<BlockTable> {
        let pt = self.page_tokens;
        let (shared, pages) = self.lookup_prefix(prompt);
        // New pages this sequence may need: its full footprint, minus the
        // shared pages, plus one page of slack for the COW of a partially
        // shared tail page.
        let total = self.pages_for(prompt.len() + reserve);
        let cow_slack = usize::from(shared % pt != 0);
        let needed = (total - pages.len()) + cow_slack;
        if self.free.len() + self.evictable_pages() < needed {
            return None;
        }
        for &pg in &pages {
            self.refcnt[pg as usize] += 1;
        }
        self.stats.prefix_lookups += 1;
        if shared > 0 {
            self.stats.prefix_hits += 1;
            self.stats.prefix_tokens_shared += shared as u64;
        }
        // Register the prefixes this sequence will itself materialize:
        // every page boundary past the shared prefix, plus the final
        // partial-page tail — fired by `advance` as prefill crosses them.
        let max_share = prompt.len().saturating_sub(1);
        let hs = prefix_hashes(&prompt[..max_share]);
        let mut pending = Vec::new();
        let mut b = shared / pt + 1;
        while b * pt <= max_share {
            if b * pt > shared {
                pending.push((b * pt, hs[b * pt]));
            }
            b += 1;
        }
        if max_share > shared && max_share % pt != 0 {
            pending.push((max_share, hs[max_share]));
        }
        let owned = vec![false; pages.len()];
        Some(BlockTable {
            pages,
            owned,
            len: shared,
            pending,
        })
    }

    /// Make position `t.len()` writable: allocate the next page at a page
    /// boundary, or copy-on-write a shared partial page. Errors (pool
    /// exhausted, even after evicting registry-only pages) leave the
    /// table untouched so the sequence can retry next step. Idempotent
    /// until [`advance`](Self::advance): the scheduler pre-reserves
    /// before building a batch and the decode kernel reserves again.
    pub fn ensure_append(&mut self, t: &mut BlockTable) -> crate::Result<()> {
        let pt = self.page_tokens;
        let slot = t.len % pt;
        if slot == 0 {
            if t.pages.len() == t.len / pt + 1 {
                return Ok(()); // already reserved for this position
            }
            debug_assert_eq!(t.pages.len(), t.len / pt, "table/page invariant");
            let pg = self.alloc_page()?;
            t.pages.push(pg);
            t.owned.push(true);
            return Ok(());
        }
        let idx = t.len / pt;
        let pg = t.pages[idx] as usize;
        if t.owned[idx] {
            return Ok(());
        }
        if self.refcnt[pg] == 1 {
            // Sole user and unregistered (registry keys hold a count):
            // adopt in place, no copy needed.
            debug_assert!(self.page_keys[pg].is_empty());
            t.owned[idx] = true;
            return Ok(());
        }
        let fresh = self.alloc_page()?;
        let d = self.d;
        for ls in &mut self.layers {
            let src = pg * pt * d;
            let dst = fresh as usize * pt * d;
            let n = slot * d;
            ls.k.copy_within(src..src + n, dst);
            ls.v.copy_within(src..src + n, dst);
        }
        self.refcnt[pg] -= 1;
        t.pages[idx] = fresh;
        t.owned[idx] = true;
        self.stats.cow_copies += 1;
        Ok(())
    }

    /// Write the K/V row of layer `bi` at position `t.len()`. The slot
    /// must exist ([`ensure_append`](Self::ensure_append) first).
    pub fn write_kv(&mut self, t: &BlockTable, bi: usize, krow: &[f32], vrow: &[f32]) {
        let pt = self.page_tokens;
        let idx = t.len / pt;
        let slot = t.len % pt;
        let pg = *t
            .pages
            .get(idx)
            .expect("kv page missing: ensure_append before write_kv") as usize;
        debug_assert!(t.owned[idx], "write into a shared page (missed COW)");
        let d = self.d;
        let off = (pg * pt + slot) * d;
        let ls = &mut self.layers[bi];
        ls.k[off..off + d].copy_from_slice(krow);
        ls.v[off..off + d].copy_from_slice(vrow);
    }

    /// Commit the row written at `t.len()` (all layers done): advance the
    /// table and fire any prefix registrations the new length crosses.
    pub fn advance(&mut self, t: &mut BlockTable) {
        t.len += 1;
        while let Some(&(at, key)) = t.pending.first() {
            if at > t.len {
                break;
            }
            t.pending.remove(0);
            let idx = (at - 1) / self.page_tokens;
            let pg = t.pages[idx];
            if !t.owned[idx] || self.registry.contains_key(&key) {
                continue;
            }
            if self.page_keys[pg as usize].is_empty() {
                self.refcnt[pg as usize] += 1;
            }
            self.registry.insert(key, pg);
            self.page_keys[pg as usize].push(key);
        }
    }

    /// Visit the contiguous K/V runs of layer `bi` covering positions
    /// `[0, n)` in ascending order: `f(j0, k_slab, v_slab)` where the
    /// slabs hold `cnt × d` floats for positions `j0 .. j0+cnt`. `n` may
    /// exceed `t.len()` by one to include a row written but not yet
    /// advanced past (the decode step attends to the row it just wrote).
    pub fn for_each_run<F: FnMut(usize, &[f32], &[f32])>(
        &self,
        t: &BlockTable,
        bi: usize,
        n: usize,
        mut f: F,
    ) {
        let pt = self.page_tokens;
        let d = self.d;
        let ls = &self.layers[bi];
        let mut j0 = 0usize;
        for &pg in &t.pages {
            if j0 >= n {
                break;
            }
            let cnt = pt.min(n - j0);
            let off = pg as usize * pt * d;
            f(j0, &ls.k[off..off + cnt * d], &ls.v[off..off + cnt * d]);
            j0 += cnt;
        }
        debug_assert!(j0 >= n, "block table covers {j0} < {n} positions");
    }

    /// Drop every page reference the table holds and reset it. Pages kept
    /// alive by the prefix registry stay resident (prefix cache) until
    /// evicted under pressure.
    pub fn release(&mut self, t: &mut BlockTable) {
        for &pg in &t.pages {
            let p = pg as usize;
            debug_assert!(self.refcnt[p] > 0, "double release of page {p}");
            self.refcnt[p] -= 1;
            if self.refcnt[p] == 0 {
                debug_assert!(self.page_keys[p].is_empty());
                self.free.push(pg);
            }
        }
        t.pages.clear();
        t.owned.clear();
        t.pending.clear();
        t.len = 0;
    }

    #[cfg(test)]
    fn refcount(&self, pg: u32) -> u32 {
        self.refcnt[pg as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: usize = 4;
    const L: usize = 2;
    const PT: usize = 4;

    fn pool(pages: usize) -> KvPool {
        KvPool::new(L, D, pages, PT)
    }

    /// Append one synthetic token row (value `val` everywhere) across all
    /// layers, mirroring a decode step's ensure → write × layers → advance.
    fn append(p: &mut KvPool, t: &mut BlockTable, val: f32) -> crate::Result<()> {
        p.ensure_append(t)?;
        let row = vec![val; D];
        for bi in 0..L {
            p.write_kv(t, bi, &row, &row);
        }
        p.advance(t);
        Ok(())
    }

    fn read_row(p: &KvPool, t: &BlockTable, bi: usize, j: usize) -> Vec<f32> {
        let mut out = Vec::new();
        p.for_each_run(t, bi, t.len(), |j0, k, _v| {
            if j >= j0 && (j - j0) * D < k.len() {
                out = k[(j - j0) * D..(j - j0 + 1) * D].to_vec();
            }
        });
        out
    }

    #[test]
    fn pages_allocate_lazily_and_release() {
        let mut p = pool(8);
        let mut t = BlockTable::new();
        assert_eq!(p.pages_in_use(), 0);
        for i in 0..6 {
            append(&mut p, &mut t, i as f32).unwrap();
        }
        // 6 tokens at 4/page → 2 pages, not a max_seq-sized slab.
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(t.n_pages(), 2);
        assert_eq!(p.bytes_in_use(), 2 * p.page_bytes());
        p.release(&mut t);
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn memory_scales_with_active_tokens_not_capacity() {
        // 4 sequences of 6 tokens in a 64-page pool use 8 pages — the
        // O(active tokens) guarantee, independent of pool capacity.
        let mut p = pool(64);
        let mut tables: Vec<BlockTable> = (0..4).map(|_| BlockTable::new()).collect();
        for t in tables.iter_mut() {
            for i in 0..6 {
                append(&mut p, t, i as f32).unwrap();
            }
        }
        assert_eq!(p.pages_in_use(), 4 * 2);
        assert_eq!(p.stats.peak_pages, 8);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let mut p = pool(2);
        let mut t = BlockTable::new();
        for i in 0..(2 * PT) {
            append(&mut p, &mut t, i as f32).unwrap();
        }
        let err = p.ensure_append(&mut t).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // The failed append left the table coherent; release still works.
        p.release(&mut t);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn prefix_sharing_bumps_refcounts_and_stats() {
        let mut p = pool(16);
        // Owner prefills a 7-token prompt: registrable prefix is 6 tokens
        // (the final token is always recomputed) → keys at 4 and 6.
        let prompt: Vec<u32> = (10..17).collect();
        let mut a = p.try_admit(&prompt, 0).unwrap();
        assert_eq!(a.len(), 0, "empty registry: nothing shared");
        for (i, _) in prompt.iter().enumerate() {
            append(&mut p, &mut a, i as f32).unwrap();
        }
        // Second admission of the same prompt shares 6 of 7 tokens.
        let b = p.try_admit(&prompt, 0).unwrap();
        assert_eq!(b.len(), 6);
        assert_eq!(b.n_pages(), 2);
        // Page 0 (full) and page 1 (tail): owner + registry + sharer.
        assert_eq!(p.refcount(b.pages[0]), 3);
        assert_eq!(p.refcount(b.pages[1]), 3);
        assert_eq!(p.stats.prefix_hits, 1);
        assert_eq!(p.stats.prefix_lookups, 2);
        assert_eq!(p.stats.prefix_tokens_shared, 6);
    }

    #[test]
    fn cow_copies_shared_tail_and_diverges() {
        let mut p = pool(16);
        let prompt: Vec<u32> = (10..17).collect();
        let mut a = p.try_admit(&prompt, 0).unwrap();
        for (i, _) in prompt.iter().enumerate() {
            append(&mut p, &mut a, i as f32).unwrap();
        }
        let mut b = p.try_admit(&prompt, 0).unwrap();
        let shared_tail = b.pages[1];
        // B writes its 7th token (slot 2 of the shared tail page): COW.
        append(&mut p, &mut b, 99.0).unwrap();
        assert_eq!(p.stats.cow_copies, 1);
        assert_ne!(b.pages[1], shared_tail, "tail page was copied");
        assert_eq!(p.refcount(shared_tail), 2, "owner + registry remain");
        assert_eq!(p.refcount(b.pages[1]), 1);
        // Rows below the divergence point were carried over …
        assert_eq!(read_row(&p, &b, 0, 4), vec![4.0; D]);
        assert_eq!(read_row(&p, &b, 1, 5), vec![5.0; D]);
        // … the diverged row is B's own, and A is undisturbed.
        assert_eq!(read_row(&p, &b, 0, 6), vec![99.0; D]);
        assert_eq!(read_row(&p, &a, 0, 6), vec![6.0; D]);
    }

    #[test]
    fn full_shared_pages_are_never_copied() {
        let mut p = pool(16);
        // 9-token prompt: max_share 8 = two full pages, both registered.
        let prompt: Vec<u32> = (0..9).collect();
        let mut a = p.try_admit(&prompt, 0).unwrap();
        for (i, _) in prompt.iter().enumerate() {
            append(&mut p, &mut a, i as f32).unwrap();
        }
        let mut b = p.try_admit(&prompt, 0).unwrap();
        assert_eq!(b.len(), 8);
        append(&mut p, &mut b, 50.0).unwrap(); // slot 0 of a new page
        assert_eq!(p.stats.cow_copies, 0);
        assert_eq!(b.n_pages(), 3);
    }

    #[test]
    fn registry_pages_survive_release_and_evict_under_pressure() {
        let mut p = pool(4);
        let prompt: Vec<u32> = (0..9).collect();
        let mut a = p.try_admit(&prompt, 0).unwrap();
        for (i, _) in prompt.iter().enumerate() {
            append(&mut p, &mut a, i as f32).unwrap();
        }
        p.release(&mut a);
        // The two registered prompt pages stay resident as prefix cache.
        assert_eq!(p.pages_in_use(), 2);
        // A different prompt needs the whole pool: registry pages evict.
        let other: Vec<u32> = (100..109).collect();
        let mut b = p.try_admit(&other, 6).expect("evictable pages count as free");
        for (i, _) in other.iter().enumerate() {
            append(&mut p, &mut b, i as f32).unwrap();
        }
        assert!(p.stats.evictions >= 1);
        // The evicted prefix no longer matches.
        let c = p.try_admit(&prompt, 0);
        assert!(c.is_none() || c.as_ref().unwrap().len() == 0);
    }

    #[test]
    fn try_admit_refuses_without_mutating() {
        let mut p = pool(2);
        let prompt: Vec<u32> = (0..12).collect(); // needs 3 pages
        assert!(p.try_admit(&prompt, 0).is_none());
        assert_eq!(p.pages_in_use(), 0);
        assert_eq!(p.stats.prefix_lookups, 0);
        // Reservation margin counts too: 8 prompt tokens fit in 2 pages,
        // but asking to reserve another page's worth does not.
        let short: Vec<u32> = (0..8).collect();
        assert!(p.try_admit(&short, PT).is_none());
        assert!(p.try_admit(&short, 0).is_some());
    }

    #[test]
    fn prefix_hash_is_position_dependent() {
        let a = prefix_hashes(&[1, 2, 3]);
        let b = prefix_hashes(&[2, 1, 3]);
        assert_eq!(a[0], b[0]);
        assert_ne!(a[2], b[2]);
        assert_ne!(a[3], b[3]);
    }
}
