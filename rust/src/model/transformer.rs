//! Pure-Rust fp32 transformer forward pass.
//!
//! Pre-LN GPT architecture: learned positional embeddings, multi-head
//! causal self-attention, GELU (tanh approximation — matching
//! `jax.nn.gelu`'s default) MLP with biases, tied LM head. Mirrors
//! `python/compile/model.py` exactly; parity is tested through the AOT
//! HLO artifacts (runtime::tests) and golden vectors.
//!
//! Two entry points:
//! * [`Transformer::forward`] — full-sequence logits, with optional
//!   activation capture (feeds Hessian collection);
//! * [`Transformer::decode_step`] — single-token step against a
//!   [`KvCache`] (the serving hot path of the native engine).
//!
//! [`KvCache`] comes in two layouts behind one enum: the contiguous
//! [`KvCacheContig`] (one `max_seq × d` slab per layer) and the paged
//! [`KvCachePaged`] (block table over a shared pool — see
//! [`super::kvpool`]). Every decode path reads and writes K/V through
//! the cache API ([`KvCache::write_kv`] / [`KvCache::for_each_run`]) and
//! runs attention through one shared helper ([`attend_cached`]), so the
//! two layouts are logit-identical by construction — pinned by tests
//! here and in `engine::native`.

use super::config::ModelConfig;
use super::kvpool::{BlockTable, SharedKvPool};
use super::weights::Checkpoint;
use crate::linalg::gemm::{sgemm_bt, sdot};

/// Weights of one transformer block, linear weights stored (out, in).
pub struct Block {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// A materialized fp32 transformer.
pub struct Transformer {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>,
    pub pos: Vec<f32>,
    pub blocks: Vec<Block>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

/// Captured per-linear-layer inputs from one forward pass: (hkey, rows of
/// the input activation matrix, in_dim). Multiple layers sharing an hkey
/// are captured once.
pub type ActSink<'a> = &'a mut dyn FnMut(&str, &[f32], usize);

impl Transformer {
    pub fn from_checkpoint(ck: &Checkpoint) -> crate::Result<Transformer> {
        let cfg = ck.config.clone();
        let get = |name: &str| -> crate::Result<Vec<f32>> { Ok(ck.tensor(name)?.data.clone()) };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            blocks.push(Block {
                ln1_g: get(&format!("blk{b}.ln1.g"))?,
                ln1_b: get(&format!("blk{b}.ln1.b"))?,
                wq: get(&format!("blk{b}.attn.wq"))?,
                wk: get(&format!("blk{b}.attn.wk"))?,
                wv: get(&format!("blk{b}.attn.wv"))?,
                wo: get(&format!("blk{b}.attn.wo"))?,
                ln2_g: get(&format!("blk{b}.ln2.g"))?,
                ln2_b: get(&format!("blk{b}.ln2.b"))?,
                w1: get(&format!("blk{b}.mlp.w1"))?,
                b1: get(&format!("blk{b}.mlp.b1"))?,
                w2: get(&format!("blk{b}.mlp.w2"))?,
                b2: get(&format!("blk{b}.mlp.b2"))?,
            });
        }
        Ok(Transformer {
            embed: get("embed")?,
            pos: get("pos_embed")?,
            lnf_g: get("lnf.g")?,
            lnf_b: get("lnf.b")?,
            cfg,
            blocks,
        })
    }

    /// Replace a named linear weight (quantized-weight application).
    pub fn set_weight(&mut self, name: &str, data: Vec<f32>) -> crate::Result<()> {
        let parts: Vec<&str> = name.split('.').collect();
        anyhow::ensure!(parts.len() == 3, "bad layer name '{name}'");
        let b: usize = parts[0]
            .strip_prefix("blk")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad block in '{name}'"))?;
        anyhow::ensure!(b < self.blocks.len(), "block {b} out of range");
        let blk = &mut self.blocks[b];
        let slot = match (parts[1], parts[2]) {
            ("attn", "wq") => &mut blk.wq,
            ("attn", "wk") => &mut blk.wk,
            ("attn", "wv") => &mut blk.wv,
            ("attn", "wo") => &mut blk.wo,
            ("mlp", "w1") => &mut blk.w1,
            ("mlp", "w2") => &mut blk.w2,
            _ => anyhow::bail!("unknown linear layer '{name}'"),
        };
        anyhow::ensure!(slot.len() == data.len(), "shape mismatch for '{name}'");
        *slot = data;
        Ok(())
    }

    pub fn get_weight(&self, name: &str) -> crate::Result<&[f32]> {
        let parts: Vec<&str> = name.split('.').collect();
        anyhow::ensure!(parts.len() == 3, "bad layer name '{name}'");
        let b: usize = parts[0]
            .strip_prefix("blk")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad block in '{name}'"))?;
        let blk = &self.blocks[b];
        Ok(match (parts[1], parts[2]) {
            ("attn", "wq") => &blk.wq,
            ("attn", "wk") => &blk.wk,
            ("attn", "wv") => &blk.wv,
            ("attn", "wo") => &blk.wo,
            ("mlp", "w1") => &blk.w1,
            ("mlp", "w2") => &blk.w2,
            _ => anyhow::bail!("unknown linear layer '{name}'"),
        })
    }

    /// Full-sequence forward: logits (T×vocab). `sink` (if set) receives
    /// the inputs of every distinct hkey (Hessian collection);
    /// `upto_block` (if set) stops after that many blocks and returns the
    /// hidden states instead of logits (block-by-block pipeline).
    pub fn forward(&self, tokens: &[u32], mut sink: Option<ActSink>) -> Vec<f32> {
        let t = tokens.len();
        let d = self.cfg.d_model;
        assert!(t <= self.cfg.max_seq, "sequence too long");
        // Embedding + positions.
        let mut x = vec![0.0f32; t * d];
        for (i, &tok) in tokens.iter().enumerate() {
            let e = &self.embed[(tok as usize) * d..(tok as usize + 1) * d];
            let p = &self.pos[i * d..(i + 1) * d];
            let row = &mut x[i * d..(i + 1) * d];
            for j in 0..d {
                row[j] = e[j] + p[j];
            }
        }
        for (bi, blk) in self.blocks.iter().enumerate() {
            self.block_forward(bi, blk, &mut x, t, &mut sink);
        }
        // Final LN + tied head.
        let mut h = vec![0.0f32; t * d];
        layernorm_rows(&x, t, d, &self.lnf_g, &self.lnf_b, &mut h);
        let v = self.cfg.vocab;
        let mut logits = vec![0.0f32; t * v];
        sgemm_bt(t, d, v, &h, &self.embed, &mut logits);
        logits
    }

    fn block_forward(
        &self,
        _bi: usize,
        blk: &Block,
        x: &mut [f32],
        t: usize,
        sink: &mut Option<ActSink>,
    ) {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let bi = _bi;

        // ---- attention ----
        let mut ln = vec![0.0f32; t * d];
        layernorm_rows(x, t, d, &blk.ln1_g, &blk.ln1_b, &mut ln);
        if let Some(s) = sink.as_mut() {
            s(&format!("blk{bi}.attn.in"), &ln, d);
        }
        let mut q = vec![0.0f32; t * d];
        let mut k = vec![0.0f32; t * d];
        let mut v = vec![0.0f32; t * d];
        sgemm_bt(t, d, d, &ln, &blk.wq, &mut q);
        sgemm_bt(t, d, d, &ln, &blk.wk, &mut k);
        sgemm_bt(t, d, d, &ln, &blk.wv, &mut v);

        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn_out = vec![0.0f32; t * d];
        let mut scores = vec![0.0f32; t];
        for h in 0..nh {
            let off = h * hd;
            for i in 0..t {
                let qi = &q[i * d + off..i * d + off + hd];
                // causal scores over j ≤ i
                let mut maxs = f32::NEG_INFINITY;
                for j in 0..=i {
                    let kj = &k[j * d + off..j * d + off + hd];
                    let s = sdot(qi, kj) * scale;
                    scores[j] = s;
                    maxs = maxs.max(s);
                }
                let mut denom = 0.0f32;
                for j in 0..=i {
                    scores[j] = (scores[j] - maxs).exp();
                    denom += scores[j];
                }
                let inv = 1.0 / denom;
                let out = &mut attn_out[i * d + off..i * d + off + hd];
                for j in 0..=i {
                    let w = scores[j] * inv;
                    if w == 0.0 {
                        continue;
                    }
                    let vj = &v[j * d + off..j * d + off + hd];
                    for l in 0..hd {
                        out[l] += w * vj[l];
                    }
                }
            }
        }
        if let Some(s) = sink.as_mut() {
            s(&format!("blk{bi}.attn.wo.in"), &attn_out, d);
        }
        let mut proj = vec![0.0f32; t * d];
        sgemm_bt(t, d, d, &attn_out, &blk.wo, &mut proj);
        for (xi, pi) in x.iter_mut().zip(&proj) {
            *xi += pi;
        }

        // ---- MLP ----
        let dff = self.cfg.d_ff;
        let mut ln2 = vec![0.0f32; t * d];
        layernorm_rows(x, t, d, &blk.ln2_g, &blk.ln2_b, &mut ln2);
        if let Some(s) = sink.as_mut() {
            s(&format!("blk{bi}.mlp.w1.in"), &ln2, d);
        }
        let mut hmid = vec![0.0f32; t * dff];
        sgemm_bt(t, d, dff, &ln2, &blk.w1, &mut hmid);
        for i in 0..t {
            let row = &mut hmid[i * dff..(i + 1) * dff];
            for (xj, bj) in row.iter_mut().zip(&blk.b1) {
                *xj = gelu(*xj + bj);
            }
        }
        if let Some(s) = sink.as_mut() {
            s(&format!("blk{bi}.mlp.w2.in"), &hmid, dff);
        }
        let mut out = vec![0.0f32; t * d];
        sgemm_bt(t, dff, d, &hmid, &blk.w2, &mut out);
        for i in 0..t {
            let row = &mut out[i * d..(i + 1) * d];
            for (xj, bj) in row.iter_mut().zip(&blk.b2) {
                *xj += bj;
            }
        }
        for (xi, oi) in x.iter_mut().zip(&out) {
            *xi += oi;
        }
    }

    /// Next-token logits for a single appended token, using cached K/V.
    /// Panics on pool exhaustion for paged caches — the batched serving
    /// path ([`crate::coordinator::generate::step_batch`]) pre-reserves
    /// the slot and stalls the sequence instead.
    pub fn decode_step(&self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let pos = cache.len();
        assert!(pos < self.cfg.max_seq, "context overflow");
        cache.ensure_append().expect("kv pool exhausted");

        let mut x = vec![0.0f32; d];
        {
            let e = &self.embed[(token as usize) * d..(token as usize + 1) * d];
            let p = &self.pos[pos * d..(pos + 1) * d];
            for j in 0..d {
                x[j] = e[j] + p[j];
            }
        }
        let mut ln = vec![0.0f32; d];
        let mut q = vec![0.0f32; d];
        let mut krow = vec![0.0f32; d];
        let mut vrow = vec![0.0f32; d];
        for (bi, blk) in self.blocks.iter().enumerate() {
            layernorm_rows(&x, 1, d, &blk.ln1_g, &blk.ln1_b, &mut ln);
            // q/k/v for this position
            matvec_bt(&blk.wq, &ln, &mut q, d, d);
            matvec_bt(&blk.wk, &ln, &mut krow, d, d);
            matvec_bt(&blk.wv, &ln, &mut vrow, d, d);
            cache.write_kv(bi, &krow, &vrow);
            // attention against cache (including the row just written)
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn = vec![0.0f32; d];
            let mut scores = vec![0.0f32; nh * (pos + 1)];
            attend_cached(cache, bi, pos + 1, d, nh, hd, &q, scale, &mut scores, &mut attn);
            let mut proj = vec![0.0f32; d];
            matvec_bt(&blk.wo, &attn, &mut proj, d, d);
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += pi;
            }
            // MLP
            let dff = self.cfg.d_ff;
            layernorm_rows(&x.clone(), 1, d, &blk.ln2_g, &blk.ln2_b, &mut ln);
            let mut hmid = vec![0.0f32; dff];
            matvec_bt(&blk.w1, &ln, &mut hmid, dff, d);
            for (xj, bj) in hmid.iter_mut().zip(&blk.b1) {
                *xj = gelu(*xj + bj);
            }
            let mut out = vec![0.0f32; d];
            matvec_bt(&blk.w2, &hmid, &mut out, d, dff);
            for ((xi, oi), bi2) in x.iter_mut().zip(&out).zip(&blk.b2) {
                *xi += oi + bi2;
            }
        }
        cache.advance();
        let mut h = vec![0.0f32; d];
        layernorm_rows(&x, 1, d, &self.lnf_g, &self.lnf_b, &mut h);
        let v = self.cfg.vocab;
        let mut logits = vec![0.0f32; v];
        for o in 0..v {
            logits[o] = sdot(&h, &self.embed[o * d..(o + 1) * d]);
        }
        logits
    }

    /// A contiguous (max_seq-preallocated) cache — the default layout.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.cfg)
    }

    /// A paged cache over `pool` with no shared prefix. Prefix-sharing
    /// admission goes through [`super::kvpool::KvPool::try_admit`] +
    /// [`KvCache::paged`] instead.
    pub fn new_paged_cache(&self, pool: &SharedKvPool) -> KvCache {
        KvCache::paged(pool, BlockTable::new())
    }
}

/// Per-block K/V cache for incremental decoding: one of two layouts
/// behind a single enum so the decode paths stay layout-agnostic and the
/// two can be pinned logit-identical against each other.
pub enum KvCache {
    Contig(KvCacheContig),
    Paged(KvCachePaged),
}

/// The contiguous layout: one `max_seq × d` K slab and V slab per layer,
/// allocated up front. Simple and indirection-free; memory is
/// O(max_seq) per sequence regardless of occupancy.
pub struct KvCacheContig {
    pub len: usize,
    pub d: usize,
    pub blocks: Vec<KvBlock>,
}

pub struct KvBlock {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// The paged layout: a block table of fixed-size pages borrowed from a
/// shared [`super::kvpool::KvPool`]. Memory is O(written tokens); pages
/// may be shared copy-on-write with other sequences (common prompt
/// prefixes). Dropping the cache releases its page references.
pub struct KvCachePaged {
    pool: SharedKvPool,
    table: BlockTable,
}

impl KvCachePaged {
    /// Pool occupancy attributable to this sequence (pages → bytes is
    /// `pool.page_bytes()`).
    pub fn n_pages(&self) -> usize {
        self.table.n_pages()
    }
}

impl Drop for KvCachePaged {
    fn drop(&mut self) {
        self.pool.lock().unwrap().release(&mut self.table);
    }
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::Contig(KvCacheContig {
            len: 0,
            d: cfg.d_model,
            blocks: (0..cfg.n_layers)
                .map(|_| KvBlock {
                    k: vec![0.0; cfg.max_seq * cfg.d_model],
                    v: vec![0.0; cfg.max_seq * cfg.d_model],
                })
                .collect(),
        })
    }

    /// Wrap a block table (fresh, or from `KvPool::try_admit` with a
    /// shared prefix already counted in `table.len()`).
    pub fn paged(pool: &SharedKvPool, table: BlockTable) -> KvCache {
        KvCache::Paged(KvCachePaged {
            pool: std::sync::Arc::clone(pool),
            table,
        })
    }

    /// Tokens whose K/V rows are committed (the next write position).
    pub fn len(&self) -> usize {
        match self {
            KvCache::Contig(c) => c.len,
            KvCache::Paged(p) => p.table.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Forget all cached rows. Paged caches return their pages to the
    /// pool; the handle stays usable for a fresh sequence.
    pub fn reset(&mut self) {
        match self {
            KvCache::Contig(c) => c.len = 0,
            KvCache::Paged(p) => p.pool.lock().unwrap().release(&mut p.table),
        }
    }

    /// Reserve the write slot for position `len()`. Contiguous caches
    /// always succeed (capacity is preallocated; overflow is the
    /// caller's `max_seq` assert). Paged caches allocate or
    /// copy-on-write a page and surface pool exhaustion as `Err` —
    /// callers either stall the sequence (serving) or propagate.
    pub fn ensure_append(&mut self) -> crate::Result<()> {
        match self {
            KvCache::Contig(_) => Ok(()),
            KvCache::Paged(p) => p.pool.lock().unwrap().ensure_append(&mut p.table),
        }
    }

    /// Write the K/V row of layer `bi` at position `len()` (reserved by
    /// [`ensure_append`](Self::ensure_append)).
    pub fn write_kv(&mut self, bi: usize, krow: &[f32], vrow: &[f32]) {
        match self {
            KvCache::Contig(c) => {
                let off = c.len * c.d;
                let blk = &mut c.blocks[bi];
                blk.k[off..off + krow.len()].copy_from_slice(krow);
                blk.v[off..off + vrow.len()].copy_from_slice(vrow);
            }
            KvCache::Paged(p) => p.pool.lock().unwrap().write_kv(&p.table, bi, krow, vrow),
        }
    }

    /// Commit the row at `len()` once every layer has written it.
    pub fn advance(&mut self) {
        match self {
            KvCache::Contig(c) => c.len += 1,
            KvCache::Paged(p) => p.pool.lock().unwrap().advance(&mut p.table),
        }
    }

    /// Visit the contiguous K/V runs of layer `bi` covering positions
    /// `[0, n)` in ascending order — one run for the contiguous layout,
    /// one per page for the paged layout. `n` may exceed `len()` by one
    /// (the row written this step). Attention iterates positions in the
    /// same order either way, so results are bit-identical.
    pub fn for_each_run<F: FnMut(usize, &[f32], &[f32])>(&self, bi: usize, n: usize, mut f: F) {
        match self {
            KvCache::Contig(c) => {
                let blk = &c.blocks[bi];
                f(0, &blk.k[..n * c.d], &blk.v[..n * c.d]);
            }
            KvCache::Paged(p) => {
                let pool = p.pool.lock().unwrap();
                pool.for_each_run(&p.table, bi, n, &mut f);
            }
        }
    }
}

/// Causal attention of one query token against cached K/V rows
/// `[0, n)` of layer `bi` — the single implementation every decode path
/// (built-in, generic-linears, batched) and both cache layouts share.
/// Per head: scores in ascending position order, max-subtracted softmax,
/// then the weighted V sum in the same order; identical arithmetic
/// regardless of how the rows are laid out, which is what makes the
/// paged path logit-identical to the contiguous one.
///
/// `q` holds the full d-dim query row; `scores` is `nh × n` scratch;
/// `attn` (d floats) is zeroed and filled here.
#[allow(clippy::too_many_arguments)]
pub fn attend_cached(
    cache: &KvCache,
    bi: usize,
    n: usize,
    d: usize,
    nh: usize,
    hd: usize,
    q: &[f32],
    scale: f32,
    scores: &mut [f32],
    attn: &mut [f32],
) {
    debug_assert!(scores.len() >= nh * n);
    attn[..d].fill(0.0);
    cache.for_each_run(bi, n, |j0, kslab, _v| {
        let rows = kslab.len() / d;
        for h in 0..nh {
            let off = h * hd;
            let qh = &q[off..off + hd];
            let srow = &mut scores[h * n..(h + 1) * n];
            for jj in 0..rows {
                let kj = &kslab[jj * d + off..jj * d + off + hd];
                srow[j0 + jj] = sdot(qh, kj) * scale;
            }
        }
    });
    for h in 0..nh {
        let srow = &mut scores[h * n..(h + 1) * n];
        let mut maxs = f32::NEG_INFINITY;
        for &s in srow.iter() {
            maxs = maxs.max(s);
        }
        let mut denom = 0.0f32;
        for s in srow.iter_mut() {
            *s = (*s - maxs).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        for s in srow.iter_mut() {
            *s *= inv;
        }
    }
    cache.for_each_run(bi, n, |j0, _k, vslab| {
        let rows = vslab.len() / d;
        for h in 0..nh {
            let off = h * hd;
            let srow = &scores[h * n..(h + 1) * n];
            let out = &mut attn[off..off + hd];
            for jj in 0..rows {
                let w = srow[j0 + jj];
                let vj = &vslab[jj * d + off..jj * d + off + hd];
                for l in 0..hd {
                    out[l] += w * vj[l];
                }
            }
        }
    });
}

/// y = W x for W stored (out, in) row-major.
fn matvec_bt(w: &[f32], x: &[f32], y: &mut [f32], out_dim: usize, in_dim: usize) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    for o in 0..out_dim {
        y[o] = sdot(x, &w[o * in_dim..(o + 1) * in_dim]);
    }
}

/// LayerNorm over the last dim of a (rows × d) buffer.
pub fn layernorm_rows(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    const EPS: f32 = 1e-5;
    for i in 0..rows {
        let row = &x[i * d..(i + 1) * d];
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        let orow = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            orow[j] = (row[j] - mean) * inv * g[j] + b[j];
        }
    }
}

/// GELU, tanh approximation (jax.nn.gelu default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Checkpoint;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 7)).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny();
        let logits = m.forward(&[1, 5, 9, 2], None);
        assert_eq!(logits.len(), 4 * m.cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality() {
        // Changing a later token must not change earlier logits.
        let m = tiny();
        let a = m.forward(&[1, 5, 9, 2], None);
        let b = m.forward(&[1, 5, 9, 200], None);
        let v = m.cfg.vocab;
        for p in 0..3 {
            for j in 0..v {
                assert_eq!(a[p * v + j], b[p * v + j], "pos {p} leaked");
            }
        }
        assert_ne!(a[3 * v..4 * v], b[3 * v..4 * v]);
    }

    #[test]
    fn decode_matches_forward() {
        let m = tiny();
        let tokens = [1u32, 17, 42, 3, 99];
        let full = m.forward(&tokens, None);
        let v = m.cfg.vocab;
        let mut cache = m.new_cache();
        for (i, &tok) in tokens.iter().enumerate() {
            let step = m.decode_step(&mut cache, tok);
            let frow = &full[i * v..(i + 1) * v];
            for j in 0..v {
                assert!(
                    (step[j] - frow[j]).abs() < 2e-3,
                    "pos {i} logit {j}: {} vs {}",
                    step[j],
                    frow[j]
                );
            }
        }
    }

    #[test]
    fn paged_decode_step_is_logit_identical_to_contig() {
        // Same tokens through a contiguous cache and a paged cache with a
        // page size that forces several pages and a partial tail: every
        // step's logits must be bit-identical, not merely close.
        let m = tiny();
        let pool = crate::model::kvpool::KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 32, 4);
        let mut contig = m.new_cache();
        let mut paged = m.new_paged_cache(&pool);
        let tokens = [1u32, 17, 42, 3, 99, 12, 7, 30, 2];
        for (i, &tok) in tokens.iter().enumerate() {
            let a = m.decode_step(&mut contig, tok);
            let b = m.decode_step(&mut paged, tok);
            assert_eq!(a, b, "step {i}: paged logits diverged");
            assert_eq!(contig.len(), paged.len());
        }
        // 9 tokens at 4 per page → 3 pages, not a max_seq slab.
        let g = pool.lock().unwrap();
        assert_eq!(g.pages_in_use(), 3);
    }

    #[test]
    fn paged_cache_reset_and_drop_release_pages() {
        let m = tiny();
        let pool = crate::model::kvpool::KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 8, 4);
        {
            let mut c = m.new_paged_cache(&pool);
            m.decode_step(&mut c, 5);
            assert_eq!(pool.lock().unwrap().pages_in_use(), 1);
            c.reset();
            assert_eq!(pool.lock().unwrap().pages_in_use(), 0);
            assert_eq!(c.len(), 0);
            m.decode_step(&mut c, 6);
            assert_eq!(pool.lock().unwrap().pages_in_use(), 1);
        } // drop
        assert_eq!(pool.lock().unwrap().pages_in_use(), 0);
    }

    #[test]
    fn activation_capture_covers_all_hkeys() {
        let m = tiny();
        let mut seen = std::collections::HashSet::new();
        let mut sink = |name: &str, rows: &[f32], in_dim: usize| {
            assert_eq!(rows.len() % in_dim, 0);
            seen.insert(name.to_string());
        };
        m.forward(&[1, 2, 3], Some(&mut sink));
        let expected: std::collections::HashSet<String> = m
            .cfg
            .linear_specs()
            .into_iter()
            .map(|s| s.hkey)
            .collect();
        assert_eq!(seen, expected);
    }

    #[test]
    fn set_weight_changes_output() {
        let mut m = tiny();
        let before = m.forward(&[1, 2, 3], None);
        let d = m.cfg.d_model;
        m.set_weight("blk0.attn.wq", vec![0.0; d * d]).unwrap();
        let after = m.forward(&[1, 2, 3], None);
        assert_ne!(before, after);
        assert!(m.set_weight("blk0.attn.bogus", vec![]).is_err());
        assert!(m.set_weight("blk9.attn.wq", vec![0.0; d * d]).is_err());
    }

    #[test]
    fn gelu_reference_values() {
        // Reference values from jax.nn.gelu (tanh approximation).
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        assert!((gelu(3.0) - 2.9963627).abs() < 1e-4);
    }
}
