//! LM evaluation: perplexity over token streams and zero-shot task
//! scoring (cloze accuracy + multiple-choice by summed log-probability).

use super::transformer::Transformer;
use crate::data::{TaskInstance, TaskKind, TaskSet, TokenStream};

/// Log-softmax of a logits row at index `target`.
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let mut denom = 0.0f64;
    for &x in logits {
        denom += ((x as f64) - maxv).exp();
    }
    (logits[target] as f64) - maxv - denom.ln()
}

/// Mean next-token cross-entropy (nats) of a model over sequences.
pub fn cross_entropy(model: &Transformer, seqs: &[&[u32]]) -> f64 {
    let v = model.cfg.vocab;
    let mut total = 0.0;
    let mut count = 0usize;
    for seq in seqs {
        let logits = model.forward(seq, None);
        for i in 0..seq.len() - 1 {
            let row = &logits[i * v..(i + 1) * v];
            total -= log_prob(row, seq[i + 1] as usize);
            count += 1;
        }
    }
    total / count.max(1) as f64
}

/// Perplexity over a stream: exp(mean cross-entropy) across
/// non-overlapping `seq_len` windows (up to `max_seqs`).
pub fn perplexity(model: &Transformer, stream: &TokenStream, seq_len: usize, max_seqs: usize) -> f64 {
    let seqs = stream.sequences(seq_len, max_seqs);
    cross_entropy(model, &seqs).exp()
}

/// Result of evaluating a task set.
#[derive(Clone, Debug)]
pub struct TaskScore {
    pub name: String,
    pub accuracy: f64,
    pub n: usize,
}

/// Score one instance: cloze → argmax over the vocab equals the answer
/// token; choice → option with max summed log-prob equals the answer.
pub fn score_instance(model: &Transformer, inst: &TaskInstance) -> bool {
    let v = model.cfg.vocab;
    match inst.kind {
        TaskKind::Cloze => {
            let logits = model.forward(&inst.context, None);
            let last = &logits[(inst.context.len() - 1) * v..inst.context.len() * v];
            let pred = last
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            pred == inst.options[inst.answer][0]
        }
        TaskKind::Choice => {
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (oi, opt) in inst.options.iter().enumerate() {
                let mut full = inst.context.clone();
                full.extend_from_slice(opt);
                let logits = model.forward(&full, None);
                let mut lp = 0.0;
                for (k, &tok) in opt.iter().enumerate() {
                    let pos = inst.context.len() + k - 1; // predicts token at pos+1
                    let row = &logits[pos * v..(pos + 1) * v];
                    lp += log_prob(row, tok as usize);
                }
                // Length-normalized, as zero-shot harnesses do.
                lp /= opt.len() as f64;
                if lp > best.0 {
                    best = (lp, oi);
                }
            }
            best.1 == inst.answer
        }
    }
}

/// Accuracy of a model on a task set.
pub fn score_tasks(model: &Transformer, tasks: &TaskSet) -> TaskScore {
    let correct = tasks
        .instances
        .iter()
        .filter(|inst| score_instance(model, inst))
        .count();
    TaskScore {
        name: tasks.name.clone(),
        accuracy: correct as f64 / tasks.len().max(1) as f64,
        n: tasks.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{TaskInstance, TaskKind, TaskSet};
    use crate::model::config::ModelConfig;
    use crate::model::weights::Checkpoint;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 3)).unwrap()
    }

    #[test]
    fn log_prob_is_normalized() {
        let logits = vec![1.0f32, 2.0, 3.0, -1.0];
        let total: f64 = (0..4).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        // An untrained model's perplexity should be near vocab size.
        let m = tiny();
        let stream = crate::data::gen::markov_stream(m.cfg.vocab as u32, 2_000, 1);
        let ppl = perplexity(&m, &stream, 32, 8);
        assert!(
            (m.cfg.vocab as f64 * 0.5..m.cfg.vocab as f64 * 2.0).contains(&ppl),
            "ppl={ppl}"
        );
    }

    #[test]
    fn task_scoring_runs_and_is_deterministic() {
        let m = tiny();
        let tasks = TaskSet {
            name: "t".into(),
            instances: vec![
                TaskInstance {
                    kind: TaskKind::Cloze,
                    context: vec![1, 5, 9],
                    options: vec![vec![12]],
                    answer: 0,
                },
                TaskInstance {
                    kind: TaskKind::Choice,
                    context: vec![1, 4],
                    options: vec![vec![7, 8], vec![9, 2]],
                    answer: 1,
                },
            ],
        };
        let a = score_tasks(&m, &tasks);
        let b = score_tasks(&m, &tasks);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.n, 2);
    }

    #[test]
    fn choice_prefers_high_probability_option() {
        // Force the model to prefer an option by constructing it from the
        // model's own greedy continuation.
        let m = tiny();
        let ctx = vec![1u32, 2, 3];
        let v = m.cfg.vocab;
        let logits = m.forward(&ctx, None);
        let last = &logits[(ctx.len() - 1) * v..ctx.len() * v];
        let greedy = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        let worst = last
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        let inst = TaskInstance {
            kind: TaskKind::Choice,
            context: ctx,
            options: vec![vec![worst], vec![greedy]],
            answer: 1,
        };
        assert!(score_instance(&m, &inst));
    }
}
