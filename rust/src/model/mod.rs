//! Transformer LM substrate: configuration, the checkpoint format shared
//! with the build-time JAX trainer, a pure-Rust fp32 forward pass (with
//! per-linear-layer activation capture for Hessian collection and a KV
//! cache for generation), quantized-weight application, and LM evaluation
//! (perplexity + zero-shot tasks).

pub mod config;
pub mod weights;
pub mod transformer;
pub mod kvpool;
pub mod quantized;
pub mod lm;

pub use config::{LinearSpec, ModelConfig};
pub use kvpool::{BlockTable, KvPool, SharedKvPool, DEFAULT_PAGE_TOKENS};
pub use transformer::{KvCache, KvCacheContig, Transformer};
pub use weights::Checkpoint;
