//! PJRT-backed LM engine: executes the AOT-lowered JAX/Pallas forward
//! (fp32 or quantized) from Rust. Static operands (weights / packed codes
//! / Kronecker factors) are marshalled to XLA literals once at load; each
//! call only builds the token literal.

use crate::linalg::KronOrtho;
use crate::model::quantized::QuantizedModel;
use crate::model::weights::Checkpoint;
use crate::model::ModelConfig;
use crate::quant::grid::GridMap;
use crate::runtime::{ArtifactSpec, Executable, Input, PjrtRuntime};

/// A compiled LM forward with cached static operands.
pub struct PjrtLm {
    exe: Executable,
    pub spec: ArtifactSpec,
    pub cfg: ModelConfig,
    /// Literals for inputs[1..] (everything but tokens).
    static_lits: Vec<xla::Literal>,
}

impl PjrtLm {
    /// fp32 forward from a checkpoint.
    pub fn fp32(
        rt: &PjrtRuntime,
        spec: &ArtifactSpec,
        ck: &Checkpoint,
    ) -> crate::Result<PjrtLm> {
        anyhow::ensure!(spec.kind == "fp32");
        let exe = rt.load(&spec.file)?;
        let mut inputs = Vec::new();
        for ispec in &spec.inputs[1..] {
            let t = ck.tensor(&ispec.name)?;
            anyhow::ensure!(
                t.dims == ispec.shape,
                "shape mismatch for '{}': ckpt {:?} vs hlo {:?}",
                ispec.name,
                t.dims,
                ispec.shape
            );
            inputs.push(Input::F32(t.data.clone(), t.dims.clone()));
        }
        let static_lits = Executable::marshal(&inputs)?;
        Ok(PjrtLm {
            exe,
            spec: spec.clone(),
            cfg: ck.config.clone(),
            static_lits,
        })
    }

    /// Quantized forward: non-linear params from the checkpoint, qparams
    /// from the quantized model (codes re-packed into int32 words; the
    /// Kronecker factors regenerated from the stored seeds).
    pub fn quant(
        rt: &PjrtRuntime,
        spec: &ArtifactSpec,
        ck: &Checkpoint,
        qm: &QuantizedModel,
    ) -> crate::Result<PjrtLm> {
        anyhow::ensure!(spec.kind == "quant");
        anyhow::ensure!(spec.bits == qm.bits, "bits mismatch");
        let exe = rt.load(&spec.file)?;
        let mut inputs = Vec::new();
        for ispec in &spec.inputs[1..] {
            if ispec.field.is_empty() {
                let t = ck.tensor(&ispec.name)?;
                inputs.push(Input::F32(t.data.clone(), t.dims.clone()));
            } else {
                inputs.push(qparam_input(qm, ispec)?);
            }
        }
        let static_lits = Executable::marshal(&inputs)?;
        Ok(PjrtLm {
            exe,
            spec: spec.clone(),
            cfg: ck.config.clone(),
            static_lits,
        })
    }

    /// Run the forward on (batch × seq) tokens (padded with 0 / truncated).
    /// Returns logits row-major (batch, seq, vocab).
    pub fn logits(&self, batch_tokens: &[Vec<u32>]) -> crate::Result<Vec<f32>> {
        let (b, t) = (self.spec.batch, self.spec.seq);
        anyhow::ensure!(batch_tokens.len() <= b, "batch too large");
        let mut toks = vec![0i32; b * t];
        for (i, seq) in batch_tokens.iter().enumerate() {
            for (j, &tok) in seq.iter().take(t).enumerate() {
                toks[i * t + j] = tok as i32;
            }
        }
        let tok_lit = Executable::marshal(&[Input::I32(toks, vec![b, t])])?;
        let mut lits: Vec<&xla::Literal> = Vec::with_capacity(1 + self.static_lits.len());
        lits.push(&tok_lit[0]);
        lits.extend(self.static_lits.iter());
        self.exe.execute_borrowed(&lits)
    }
}

/// Build one qparam input (matching aot.py's `qparam_fields` order and
/// semantics) from a quantized layer.
fn qparam_input(qm: &QuantizedModel, ispec: &crate::runtime::InputSpec) -> crate::Result<Input> {
    let layer = qm.layer(&ispec.name)?;
    // The AOT Pallas artifacts bit-unpack scalar integer codes; a layer
    // storing vector-codebook indices (`.qz` v3, the vq rounder) has no
    // scalar codes to marshal — route it to the native engine instead.
    anyhow::ensure!(
        layer.layout == crate::quant::packed::CodeLayout::Scalar,
        "layer '{}' stores vector-codebook indices; the AOT Pallas artifacts \
         decode scalar codes — use the native engine for vq models",
        layer.name
    );
    let (m, n) = (layer.m, layer.n);
    let bits = layer.bits;
    let qmax = crate::quant::grid::levels(bits) as f64;
    Ok(match ispec.field.as_str() {
        "words" => {
            anyhow::ensure!(bits == 2 || bits == 4);
            let per = (32 / bits) as usize;
            let nw = n.div_ceil(per);
            let codes = layer.codes();
            let mut words = vec![0i32; m * nw];
            for i in 0..m {
                for j in 0..n {
                    let w = j / per;
                    let k = j % per;
                    words[i * nw + w] |=
                        (codes[(i, j)] as i32) << (k * bits as usize);
                }
            }
            Input::I32(words, vec![m, nw])
        }
        "codes" => {
            let codes = layer.codes();
            let raw: Vec<u8> = codes.data.iter().map(|&c| c as u8).collect();
            Input::U8(raw, vec![m, n])
        }
        "rowscale" => {
            let v: Vec<f32> = match &layer.post.grid {
                GridMap::PerRow { lo, hi, .. } => lo
                    .iter()
                    .zip(hi)
                    .map(|(l, h)| ((h - l) / qmax) as f32)
                    .collect(),
                GridMap::Global { s, .. } => vec![(2.0 * s / qmax) as f32; m],
            };
            Input::F32(v, vec![m])
        }
        "rowoff" => {
            let v: Vec<f32> = match &layer.post.grid {
                GridMap::PerRow { lo, .. } => lo.iter().map(|&l| l as f32).collect(),
                GridMap::Global { s, .. } => vec![-(*s as f32); m],
            };
            Input::F32(v, vec![m])
        }
        "dinv" => {
            let v: Vec<f32> = match &layer.post.d_tilde {
                Some(d) => d.iter().map(|&x| (1.0 / x) as f32).collect(),
                None => vec![1.0; n],
            };
            Input::F32(v, vec![n])
        }
        "vL" | "vR" | "vperm" | "uL" | "uR" | "uperm" => {
            // The AOT Pallas artifacts are compiled around the Kronecker
            // factor structure; layers quantized with another transform
            // backend must use the native engine.
            anyhow::ensure!(
                layer.post.transform == crate::linalg::TransformKind::Kron,
                "PJRT artifact path supports the kron transform only; layer '{}' \
                 was quantized with '{}' (serve it with the native engine)",
                layer.name,
                layer.post.transform
            );
            if ispec.field.starts_with('v') {
                kron_input(layer.post.v_seed, n, layer.post.permute, &ispec.field)?
            } else {
                kron_input(layer.post.u_seed, m, layer.post.permute, &ispec.field)?
            }
        }
        other => anyhow::bail!("unknown qparam field '{other}'"),
    })
}

fn kron_input(seed: u64, dim: usize, permute: bool, field: &str) -> crate::Result<Input> {
    let k = KronOrtho::from_seed_with(seed, dim, permute);
    Ok(match field.chars().last() {
        Some('L') => Input::F32(
            k.left.data.iter().map(|&x| x as f32).collect(),
            vec![k.p, k.p],
        ),
        Some('R') => Input::F32(
            k.right.data.iter().map(|&x| x as f32).collect(),
            vec![k.q, k.q],
        ),
        Some('m') => Input::I32(k.perm.iter().map(|&p| p as i32).collect(), vec![dim]),
        _ => anyhow::bail!("unknown kron artifact field '{field}'"),
    })
}
