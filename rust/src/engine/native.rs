//! Native decode engine: a generic single-token decode step whose six
//! per-block linears are pluggable, with an fp32 implementation and a
//! quantized implementation that reads packed codes directly
//! (unpack-dequant fused into the matvec) and applies the incoherence
//! transform through the pluggable [`Transform`] subsystem — the seeded
//! Kronecker multiply or the O(n log n) randomized Hadamard butterfly,
//! whichever the artifact's layers record — the Rust twin of the Pallas
//! kernel path.
//!
//! Both code layouts decode through the same kernels: scalar layers
//! bit-unpack 2/3/4-bit integer codes; vector-quantized layers (`.qz`
//! v3, the `vq` rounder) expand one byte-aligned group index per 8
//! weights through a per-layer codebook LUT
//! ([`crate::quant::grid::VqLut`], regenerated from the layer's stored
//! seed), so serving and [`LinearOps::apply_batch`] work unchanged on
//! codebook artifacts.
//!
//! Batched serving path: [`LinearOps::apply_batch`] applies one linear to
//! a whole batch of query vectors. The quantized implementation decodes a
//! [`BATCH_TILE`]-row tile of packed codes *once* into a scratch buffer
//! and reuses it for every query in the batch (`linalg::gemm::
//! sgemm_bt_fused`), so the bit-unpacking cost is amortized across the
//! batch instead of being paid per query as in [`QuantLinear::apply`].
//! [`decode_step_batch`] runs one decode step for several sequences at
//! independent cache positions — the substrate of the serving
//! coordinator's continuous batching loop.

use crate::linalg::gemm::{sdot, sgemm_bt, sgemm_bt_fused};
use crate::linalg::{make_transform, Transform};
use crate::model::quantized::QuantizedModel;
use crate::model::transformer::{attend_cached, gelu, layernorm_rows, KvCache, Transformer};
use crate::quant::grid::{Codebook, GridMap, VqLut, VQ_GROUP};
use crate::quant::packed::{CodeLayout, QuantizedLayer};
use crate::util::sync::lock_unpoisoned;
use std::sync::Arc;

/// Linear-layer slots within a block, forward order.
pub const SLOTS: [&str; 6] = ["attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.w1", "mlp.w2"];

/// Rows of packed codes decoded per tile in the fused batch kernel. Big
/// enough to amortize per-tile overhead, small enough that a tile
/// (BATCH_TILE × n f32) stays cache-resident while the batch streams it.
pub const BATCH_TILE: usize = 32;

/// Pluggable linear application: y = W x for block `blk`, slot `slot`.
pub trait LinearOps {
    fn apply(&self, blk: usize, slot: usize, x: &[f32], y: &mut [f32]);
    fn name(&self) -> &'static str;

    /// Batched form: `ys[b] = W xs[b]` for `b in 0..batch` (row-major
    /// `batch × n` in, `batch × m` out). The default loops [`apply`]
    /// per query; implementations override it when they can amortize
    /// work across the batch.
    ///
    /// [`apply`]: LinearOps::apply
    fn apply_batch(&self, blk: usize, slot: usize, xs: &[f32], batch: usize, ys: &mut [f32]) {
        if batch == 0 {
            return;
        }
        let n = xs.len() / batch;
        let m = ys.len() / batch;
        for b in 0..batch {
            self.apply(blk, slot, &xs[b * n..(b + 1) * n], &mut ys[b * m..(b + 1) * m]);
        }
    }
}

/// fp32 linears straight from the model weights.
pub struct FpLinears<'a> {
    pub model: &'a Transformer,
}

impl<'a> LinearOps for FpLinears<'a> {
    fn apply(&self, blk: usize, slot: usize, x: &[f32], y: &mut [f32]) {
        let b = &self.model.blocks[blk];
        let w: &[f32] = match slot {
            0 => &b.wq,
            1 => &b.wk,
            2 => &b.wv,
            3 => &b.wo,
            4 => &b.w1,
            _ => &b.w2,
        };
        let n = x.len();
        for (o, yo) in y.iter_mut().enumerate() {
            *yo = sdot(x, &w[o * n..(o + 1) * n]);
        }
    }

    fn name(&self) -> &'static str {
        "fp32"
    }

    fn apply_batch(&self, blk: usize, slot: usize, xs: &[f32], batch: usize, ys: &mut [f32]) {
        if batch == 0 {
            return;
        }
        let b = &self.model.blocks[blk];
        let w: &[f32] = match slot {
            0 => &b.wq,
            1 => &b.wk,
            2 => &b.wv,
            3 => &b.wo,
            4 => &b.w1,
            _ => &b.w2,
        };
        let n = xs.len() / batch;
        let m = ys.len() / batch;
        sgemm_bt(batch, n, m, xs, w, ys);
    }
}

/// One quantized linear layer prepared for the native hot path. The input
/// and output incoherence operators are regenerated from the layer's
/// `(transform, seed)` record through [`make_transform`] — the engine is
/// backend-agnostic.
pub struct QuantLinear {
    pub layer: QuantizedLayer,
    rowscale: Vec<f32>,
    rowoff: Vec<f32>,
    dinv: Option<Vec<f32>>,
    vtr: Option<Arc<dyn Transform>>,
    utr: Option<Arc<dyn Transform>>,
    /// Codebook expansion state for vq layers (`None` for scalar codes):
    /// the per-layer LUT regenerated from the layer's stored seed.
    vq: Option<VqState>,
}

/// Per-layer vector-codebook decode state: the f32 LUT plus the packed
/// geometry (⌈n/8⌉ groups per row, `bits` bytes per group index).
struct VqState {
    lut: VqLut,
    groups_per_row: usize,
    bytes_per_group: usize,
}

impl QuantLinear {
    pub fn new(layer: QuantizedLayer) -> QuantLinear {
        let (m, _n) = (layer.m, layer.n);
        let q = crate::quant::grid::levels(layer.bits) as f32;
        let (rowscale, rowoff) = match &layer.post.grid {
            GridMap::PerRow { lo, hi, .. } => (
                lo.iter()
                    .zip(hi)
                    .map(|(l, h)| ((h - l) as f32) / q)
                    .collect(),
                lo.iter().map(|&l| l as f32).collect(),
            ),
            GridMap::Global { s, .. } => (
                vec![2.0 * (*s as f32) / q; m],
                vec![-(*s as f32); m],
            ),
        };
        let dinv = layer
            .post
            .d_tilde
            .as_ref()
            .map(|d| d.iter().map(|&x| (1.0 / x) as f32).collect());
        let (vtr, utr) = if layer.post.incoherent {
            let kind = layer.post.transform;
            (
                Some(make_transform(kind, layer.post.v_seed, layer.n, layer.post.permute)),
                Some(make_transform(kind, layer.post.u_seed, layer.m, layer.post.permute)),
            )
        } else {
            (None, None)
        };
        let vq = match layer.layout {
            CodeLayout::Scalar => None,
            CodeLayout::Vq { cb_seed } => {
                // Both expects are re-validation of artifact-load checks:
                // QuantModel::deserialize rejects vq layers whose bits are
                // outside E8's supported range, and every E8 codebook is
                // built with a LUT. Reaching either panic means the
                // artifact was mutated after validation.
                let cb = Codebook::e8(layer.bits, cb_seed)
                    // preflight: allow(panic, "bits re-validated; checked at artifact load")
                    .expect("vq layer bits validated at construction/deserialize");
                Some(VqState {
                    // preflight: allow(panic, "e8 codebooks are always built with a LUT")
                    lut: cb.lut_f32().expect("e8 codebooks always have a LUT"),
                    groups_per_row: layer.n.div_ceil(VQ_GROUP),
                    bytes_per_group: layer.bits as usize,
                })
            }
        };
        QuantLinear {
            layer,
            rowscale,
            rowoff,
            dinv,
            vtr,
            utr,
            vq,
        }
    }

    /// y = Ŵ x without materializing Ŵ: optional diag + incoherence
    /// transform on the input, fused unpack-dequant matvec over packed
    /// codes, optional inverse transform on the output.
    pub fn apply(&self, x: &[f32], y: &mut [f32], scratch: &mut Scratch) {
        let (m, n) = (self.layer.m, self.layer.n);
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), m);
        scratch.ensure(n.max(m));
        let xbuf = &mut scratch.a[..n];
        xbuf.copy_from_slice(x);
        if let Some(d) = &self.dinv {
            for (xi, di) in xbuf.iter_mut().zip(d) {
                *xi *= di;
            }
        }
        if let Some(v) = &self.vtr {
            let (tmp, rest) = scratch.b.split_at_mut(n);
            v.forward_f32(&scratch.a[..n], tmp, &mut rest[..n]);
            scratch.a[..n].copy_from_slice(tmp);
        }
        let xbuf = &scratch.a[..n];
        let xsum: f32 = xbuf.iter().sum();
        // Fused unpack + matvec over the packed bitstream.
        let target: &mut [f32] = if self.utr.is_some() {
            &mut scratch.b[..m]
        } else {
            y
        };
        self.matvec_codes(xbuf, target);
        for i in 0..m {
            target[i] = self.rowscale[i] * target[i] + self.rowoff[i] * xsum;
        }
        if let Some(u) = &self.utr {
            let (bbuf, rest) = scratch.b.split_at_mut(m);
            u.inverse_f32(bbuf, y, &mut rest[..m]);
        }
    }

    /// Read the group index for (row `i`, group `g`) straight from the
    /// packed bytes. Vq group indices are `8·bits` bits = `bits` bytes
    /// wide, so every group is byte-aligned: a plain little-endian read.
    #[inline]
    fn read_group_index(&self, vq: &VqState, i: usize, g: usize) -> u64 {
        let off = (i * vq.groups_per_row + g) * vq.bytes_per_group;
        let mut v = 0u64;
        for (b, &byte) in self.layer.packed[off..off + vq.bytes_per_group]
            .iter()
            .enumerate()
        {
            v |= (byte as u64) << (8 * b);
        }
        v
    }

    /// raw_i = Σ_j codes[i,j]·x[j] for a vq layer: expand each group
    /// index through the per-layer LUT into an 8-wide stack buffer and
    /// accumulate — no byte-level bit extraction at all.
    fn matvec_vq(&self, vq: &VqState, x: &[f32], out: &mut [f32]) {
        let (m, n) = (self.layer.m, self.layer.n);
        let mut buf = [0.0f32; VQ_GROUP];
        for (i, o) in out.iter_mut().enumerate().take(m) {
            let mut acc = 0.0f32;
            for g in 0..vq.groups_per_row {
                let r = (n - g * VQ_GROUP).min(VQ_GROUP);
                vq.lut.decode(self.read_group_index(vq, i, g), &mut buf[..r]);
                let xs = &x[g * VQ_GROUP..g * VQ_GROUP + r];
                for j in 0..r {
                    acc += buf[j] * xs[j];
                }
            }
            *o = acc;
        }
    }

    /// raw_i = Σ_j codes[i,j]·x[j], reading codes straight from the packed
    /// bitstream (or through the codebook LUT for vq layers).
    fn matvec_codes(&self, x: &[f32], out: &mut [f32]) {
        if let Some(vq) = &self.vq {
            return self.matvec_vq(vq, x, out);
        }
        let (m, n) = (self.layer.m, self.layer.n);
        let bits = self.layer.bits as usize;
        let packed = &self.layer.packed;
        match bits {
            2 => {
                // 4 codes per byte; row starts are byte-aligned iff n % 4 == 0.
                if n % 4 == 0 {
                    let bpr = n / 4;
                    for i in 0..m {
                        let row = &packed[i * bpr..(i + 1) * bpr];
                        let mut acc = 0.0f32;
                        let mut j = 0;
                        for &b in row {
                            acc += (b & 3) as f32 * x[j]
                                + ((b >> 2) & 3) as f32 * x[j + 1]
                                + ((b >> 4) & 3) as f32 * x[j + 2]
                                + ((b >> 6) & 3) as f32 * x[j + 3];
                            j += 4;
                        }
                        out[i] = acc;
                    }
                } else {
                    self.matvec_generic(x, out);
                }
            }
            4 => {
                if n % 2 == 0 {
                    let bpr = n / 2;
                    for i in 0..m {
                        let row = &packed[i * bpr..(i + 1) * bpr];
                        let mut acc = 0.0f32;
                        let mut j = 0;
                        for &b in row {
                            acc += (b & 15) as f32 * x[j] + ((b >> 4) & 15) as f32 * x[j + 1];
                            j += 2;
                        }
                        out[i] = acc;
                    }
                } else {
                    self.matvec_generic(x, out);
                }
            }
            _ => self.matvec_generic(x, out),
        }
    }

    fn matvec_generic(&self, x: &[f32], out: &mut [f32]) {
        let (m, n) = (self.layer.m, self.layer.n);
        let mut row = vec![0u8; n];
        for i in 0..m {
            self.layer.codes_row(i, &mut row);
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += row[j] as f32 * x[j];
            }
            out[i] = acc;
        }
    }

    /// Decode rows `[i0, i1)` of the packed codes into `out`
    /// ((i1−i0) × n f32, raw code values — codebook points for vq
    /// layers). The tile decode of the fused batch kernel: paid once per
    /// tile, amortized over the whole batch.
    fn decode_rows(&self, i0: usize, i1: usize, out: &mut [f32]) {
        let n = self.layer.n;
        let bits = self.layer.bits as usize;
        debug_assert_eq!(out.len(), (i1 - i0) * n);
        if let Some(vq) = &self.vq {
            for i in i0..i1 {
                let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
                for g in 0..vq.groups_per_row {
                    let r = (n - g * VQ_GROUP).min(VQ_GROUP);
                    vq.lut.decode(
                        self.read_group_index(vq, i, g),
                        &mut orow[g * VQ_GROUP..g * VQ_GROUP + r],
                    );
                }
            }
            return;
        }
        let packed = &self.layer.packed;
        match bits {
            2 if n % 4 == 0 => {
                let bpr = n / 4;
                for i in i0..i1 {
                    let row = &packed[i * bpr..(i + 1) * bpr];
                    let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
                    let mut j = 0;
                    for &b in row {
                        orow[j] = (b & 3) as f32;
                        orow[j + 1] = ((b >> 2) & 3) as f32;
                        orow[j + 2] = ((b >> 4) & 3) as f32;
                        orow[j + 3] = ((b >> 6) & 3) as f32;
                        j += 4;
                    }
                }
            }
            4 if n % 2 == 0 => {
                let bpr = n / 2;
                for i in i0..i1 {
                    let row = &packed[i * bpr..(i + 1) * bpr];
                    let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
                    let mut j = 0;
                    for &b in row {
                        orow[j] = (b & 15) as f32;
                        orow[j + 1] = ((b >> 4) & 15) as f32;
                        j += 2;
                    }
                }
            }
            _ => {
                let mut row = vec![0u8; n];
                for i in i0..i1 {
                    self.layer.codes_row(i, &mut row);
                    let orow = &mut out[(i - i0) * n..(i - i0 + 1) * n];
                    for (o, &c) in orow.iter_mut().zip(&row) {
                        *o = c as f32;
                    }
                }
            }
        }
    }

    /// Batched `ys[b] = Ŵ xs[b]` without materializing Ŵ: per-query input
    /// transform (diag + forward incoherence transform), then the fused
    /// tile kernel — each [`BATCH_TILE`]-row tile of packed codes is
    /// decoded *once* and multiplied against every query — then per-query
    /// grid affine and inverse output transform. Equivalent to calling
    /// [`apply`](Self::apply) per query, at a fraction of the unpack cost.
    pub fn apply_batch(&self, xs: &[f32], batch: usize, ys: &mut [f32], s: &mut BatchScratch) {
        let (m, n) = (self.layer.m, self.layer.n);
        debug_assert_eq!(xs.len(), batch * n);
        debug_assert_eq!(ys.len(), batch * m);
        if batch == 0 {
            return;
        }
        s.ensure(batch, n, m);
        for b in 0..batch {
            let dst = &mut s.xt[b * n..(b + 1) * n];
            dst.copy_from_slice(&xs[b * n..(b + 1) * n]);
            if let Some(d) = &self.dinv {
                for (xi, di) in dst.iter_mut().zip(d) {
                    *xi *= di;
                }
            }
        }
        if let Some(v) = &self.vtr {
            let (tmp, rest) = s.tmp.split_at_mut(n);
            for b in 0..batch {
                let row = &mut s.xt[b * n..(b + 1) * n];
                v.forward_f32(&row[..], tmp, &mut rest[..n]);
                row.copy_from_slice(tmp);
            }
        }
        for b in 0..batch {
            s.xsum[b] = s.xt[b * n..(b + 1) * n].iter().sum();
        }
        {
            let raw: &mut [f32] = if self.utr.is_some() {
                &mut s.raw[..batch * m]
            } else {
                &mut ys[..]
            };
            sgemm_bt_fused(
                batch,
                n,
                m,
                BATCH_TILE,
                &s.xt[..batch * n],
                &|i0: usize, i1: usize, buf: &mut [f32]| self.decode_rows(i0, i1, buf),
                raw,
            );
            for b in 0..batch {
                let xsum = s.xsum[b];
                let rrow = &mut raw[b * m..(b + 1) * m];
                for i in 0..m {
                    rrow[i] = self.rowscale[i] * rrow[i] + self.rowoff[i] * xsum;
                }
            }
        }
        if let Some(u) = &self.utr {
            for b in 0..batch {
                u.inverse_f32(
                    &s.raw[b * m..(b + 1) * m],
                    &mut ys[b * m..(b + 1) * m],
                    &mut s.tmp[..m],
                );
            }
        }
    }
}

/// Reusable scratch buffers (decode loop is allocation-free after warmup).
pub struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch {
            a: Vec::new(),
            b: Vec::new(),
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.a.len() < n {
            self.a.resize(n, 0.0);
        }
        if self.b.len() < 2 * n {
            self.b.resize(2 * n, 0.0);
        }
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Reusable buffers for the batched fused kernel (transformed inputs,
/// raw code-space products, per-query input sums, transform scratch).
pub struct BatchScratch {
    xt: Vec<f32>,
    raw: Vec<f32>,
    xsum: Vec<f32>,
    tmp: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch {
            xt: Vec::new(),
            raw: Vec::new(),
            xsum: Vec::new(),
            tmp: Vec::new(),
        }
    }

    fn ensure(&mut self, batch: usize, n: usize, m: usize) {
        if self.xt.len() < batch * n {
            self.xt.resize(batch * n, 0.0);
        }
        if self.raw.len() < batch * m {
            self.raw.resize(batch * m, 0.0);
        }
        if self.xsum.len() < batch {
            self.xsum.resize(batch, 0.0);
        }
        let nm = 2 * n.max(m);
        if self.tmp.len() < nm {
            self.tmp.resize(nm, 0.0);
        }
    }
}

impl Default for BatchScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Quantized linears for a whole model, indexed blk*6 + slot.
pub struct QuantLinears {
    pub linears: Vec<QuantLinear>,
    scratch: std::sync::Mutex<Scratch>,
    batch_scratch: std::sync::Mutex<BatchScratch>,
}

impl QuantLinears {
    pub fn from_model(qm: &QuantizedModel) -> crate::Result<QuantLinears> {
        let cfg = &qm.config;
        let mut linears = Vec::new();
        for b in 0..cfg.n_layers {
            for slot in SLOTS {
                let name = format!("blk{b}.{slot}");
                linears.push(QuantLinear::new(qm.layer(&name)?.clone()));
            }
        }
        Ok(QuantLinears {
            linears,
            scratch: std::sync::Mutex::new(Scratch::new()),
            batch_scratch: std::sync::Mutex::new(BatchScratch::new()),
        })
    }
}

impl LinearOps for QuantLinears {
    fn apply(&self, blk: usize, slot: usize, x: &[f32], y: &mut [f32]) {
        let lin = &self.linears[blk * 6 + slot];
        lin.apply(x, y, &mut lock_unpoisoned(&self.scratch));
    }

    fn name(&self) -> &'static str {
        "native-quant"
    }

    fn apply_batch(&self, blk: usize, slot: usize, xs: &[f32], batch: usize, ys: &mut [f32]) {
        let lin = &self.linears[blk * 6 + slot];
        lin.apply_batch(xs, batch, ys, &mut lock_unpoisoned(&self.batch_scratch));
    }
}

/// Generic single-token decode step: uses `model` for embeddings / LNs /
/// biases / attention and `lin` for the six linears per block. Mirrors
/// `Transformer::decode_step` (tested for equality with FpLinears).
pub fn decode_step_with(
    model: &Transformer,
    lin: &dyn LinearOps,
    cache: &mut KvCache,
    token: u32,
) -> Vec<f32> {
    let d = model.cfg.d_model;
    let nh = model.cfg.n_heads;
    let hd = model.cfg.head_dim();
    let pos = cache.len();
    assert!(pos < model.cfg.max_seq, "context overflow");
    // Single-sequence decode has no admission control to shed to; the
    // batch path (decode_step_batch) is the one servers drive, and its
    // callers pre-reserve via step_batch.
    // preflight: allow(panic, "pool-exhaustion backstop; serving path pre-reserves")
    cache.ensure_append().expect("kv pool exhausted");

    let mut x = vec![0.0f32; d];
    {
        let e = &model.embed[(token as usize) * d..(token as usize + 1) * d];
        let p = &model.pos[pos * d..(pos + 1) * d];
        for j in 0..d {
            x[j] = e[j] + p[j];
        }
    }
    let mut ln = vec![0.0f32; d];
    let mut q = vec![0.0f32; d];
    let mut krow = vec![0.0f32; d];
    let mut vrow = vec![0.0f32; d];
    for (bi, blk) in model.blocks.iter().enumerate() {
        layernorm_rows(&x, 1, d, &blk.ln1_g, &blk.ln1_b, &mut ln);
        lin.apply(bi, 0, &ln, &mut q);
        lin.apply(bi, 1, &ln, &mut krow);
        lin.apply(bi, 2, &ln, &mut vrow);
        cache.write_kv(bi, &krow, &vrow);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; d];
        let mut scores = vec![0.0f32; nh * (pos + 1)];
        attend_cached(cache, bi, pos + 1, d, nh, hd, &q, scale, &mut scores, &mut attn);
        let mut proj = vec![0.0f32; d];
        lin.apply(bi, 3, &attn, &mut proj);
        for (xi, pi) in x.iter_mut().zip(&proj) {
            *xi += pi;
        }
        let dff = model.cfg.d_ff;
        layernorm_rows(&x, 1, d, &blk.ln2_g, &blk.ln2_b, &mut ln);
        let mut hmid = vec![0.0f32; dff];
        lin.apply(bi, 4, &ln, &mut hmid);
        for (xj, bj) in hmid.iter_mut().zip(&blk.b1) {
            *xj = gelu(*xj + bj);
        }
        let mut out = vec![0.0f32; d];
        lin.apply(bi, 5, &hmid, &mut out);
        for ((xi, oi), bi2) in x.iter_mut().zip(&out).zip(&blk.b2) {
            *xi += oi + bi2;
        }
    }
    cache.advance();
    let mut h = vec![0.0f32; d];
    layernorm_rows(&x, 1, d, &model.lnf_g, &model.lnf_b, &mut h);
    let v = model.cfg.vocab;
    let mut logits = vec![0.0f32; v];
    for o in 0..v {
        logits[o] = sdot(&h, &model.embed[o * d..(o + 1) * d]);
    }
    logits
}

/// One decode step for a batch of independent sequences: feed `tokens[b]`
/// to the sequence behind `caches[b]` (each at its own position — new
/// requests join and finished ones leave between steps, so positions
/// differ) and return the next-token logits, row-major `batch × vocab`.
///
/// The six per-block linears and the LM head run batched
/// ([`LinearOps::apply_batch`] / `sgemm_bt`); embeddings, LayerNorm and
/// attention are per-sequence (attention spans differ). Matches
/// [`decode_step_with`] per sequence (tested for equality).
pub fn decode_step_batch(
    model: &Transformer,
    lin: &dyn LinearOps,
    caches: &mut [&mut KvCache],
    tokens: &[u32],
) -> Vec<f32> {
    let bsz = tokens.len();
    assert_eq!(caches.len(), bsz, "one cache per token");
    if bsz == 0 {
        return Vec::new();
    }
    let d = model.cfg.d_model;
    let nh = model.cfg.n_heads;
    let hd = model.cfg.head_dim();
    let dff = model.cfg.d_ff;

    let mut x = vec![0.0f32; bsz * d];
    for (b, (&tok, cache)) in tokens.iter().zip(caches.iter()).enumerate() {
        let pos = cache.len();
        assert!(pos < model.cfg.max_seq, "context overflow (seq {b})");
        let e = &model.embed[(tok as usize) * d..(tok as usize + 1) * d];
        let p = &model.pos[pos * d..(pos + 1) * d];
        let row = &mut x[b * d..(b + 1) * d];
        for j in 0..d {
            row[j] = e[j] + p[j];
        }
    }
    // Reserve every sequence's write slot up front (allocation / COW for
    // paged caches). The serving scheduler pre-reserves via step_batch
    // and stalls sequences the pool cannot cover, so this panic is the
    // "caller skipped admission control" backstop, not a serving path.
    for (b, cache) in caches.iter_mut().enumerate() {
        // preflight: allow(panic, "admission-control backstop; step_batch pre-reserves")
        cache.ensure_append().unwrap_or_else(|e| panic!("kv pool exhausted (seq {b}): {e}"));
    }

    let mut ln = vec![0.0f32; bsz * d];
    let mut q = vec![0.0f32; bsz * d];
    let mut kbuf = vec![0.0f32; bsz * d];
    let mut vbuf = vec![0.0f32; bsz * d];
    let mut attn = vec![0.0f32; bsz * d];
    let mut proj = vec![0.0f32; bsz * d];
    let mut hmid = vec![0.0f32; bsz * dff];
    let mut mlp = vec![0.0f32; bsz * d];
    // One scores buffer sized for the longest sequence in the batch.
    let max_pos = caches.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut scores = vec![0.0f32; nh * (max_pos + 1)];
    // Wall-clock spent in the batched linears (the fused GEMM / LUT
    // decode path), credited to the caller's obs stage ledger so the
    // scheduler's per-step span can attribute GEMM vs attention time.
    let mut linear_s = 0.0f64;
    for (bi, blk) in model.blocks.iter().enumerate() {
        layernorm_rows(&x, bsz, d, &blk.ln1_g, &blk.ln1_b, &mut ln);
        let tl = std::time::Instant::now();
        lin.apply_batch(bi, 0, &ln, bsz, &mut q);
        lin.apply_batch(bi, 1, &ln, bsz, &mut kbuf);
        lin.apply_batch(bi, 2, &ln, bsz, &mut vbuf);
        linear_s += tl.elapsed().as_secs_f64();
        // Scatter K/V rows into each sequence's cache at its own position.
        for (b, cache) in caches.iter_mut().enumerate() {
            cache.write_kv(bi, &kbuf[b * d..(b + 1) * d], &vbuf[b * d..(b + 1) * d]);
        }
        // Attention per sequence (spans differ across the batch).
        let scale = 1.0 / (hd as f32).sqrt();
        for (b, cache) in caches.iter().enumerate() {
            let n = cache.len() + 1;
            attend_cached(
                cache,
                bi,
                n,
                d,
                nh,
                hd,
                &q[b * d..(b + 1) * d],
                scale,
                &mut scores[..nh * n],
                &mut attn[b * d..(b + 1) * d],
            );
        }
        let tl = std::time::Instant::now();
        lin.apply_batch(bi, 3, &attn, bsz, &mut proj);
        linear_s += tl.elapsed().as_secs_f64();
        for (xi, pi) in x.iter_mut().zip(&proj) {
            *xi += pi;
        }
        layernorm_rows(&x, bsz, d, &blk.ln2_g, &blk.ln2_b, &mut ln);
        let tl = std::time::Instant::now();
        lin.apply_batch(bi, 4, &ln, bsz, &mut hmid);
        linear_s += tl.elapsed().as_secs_f64();
        for b in 0..bsz {
            let row = &mut hmid[b * dff..(b + 1) * dff];
            for (xj, bj) in row.iter_mut().zip(&blk.b1) {
                *xj = gelu(*xj + bj);
            }
        }
        let tl = std::time::Instant::now();
        lin.apply_batch(bi, 5, &hmid, bsz, &mut mlp);
        linear_s += tl.elapsed().as_secs_f64();
        for b in 0..bsz {
            let orow = &mlp[b * d..(b + 1) * d];
            let xrow = &mut x[b * d..(b + 1) * d];
            for ((xi, oi), bi2) in xrow.iter_mut().zip(orow).zip(&blk.b2) {
                *xi += oi + bi2;
            }
        }
    }
    for cache in caches.iter_mut() {
        cache.advance();
    }
    crate::obs::trace::credit_stage("decode_linear", linear_s);
    let mut h = vec![0.0f32; bsz * d];
    layernorm_rows(&x, bsz, d, &model.lnf_g, &model.lnf_b, &mut h);
    let v = model.cfg.vocab;
    let mut logits = vec![0.0f32; bsz * v];
    sgemm_bt(bsz, d, v, &h, &model.embed, &mut logits);
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::model::config::ModelConfig;
    use crate::model::kvpool::KvPool;
    use crate::model::weights::Checkpoint;
    use crate::quant::{quantize_layer, Method, Processing, QuantConfig};
    use crate::util::testkit::random_hessian;

    fn tiny() -> Transformer {
        let cfg = ModelConfig::sized("t", 32, 2, 4, 64);
        Transformer::from_checkpoint(&Checkpoint::random(&cfg, 7)).unwrap()
    }

    #[test]
    fn fp_linears_match_builtin_decode() {
        let m = tiny();
        let lin = FpLinears { model: &m };
        let tokens = [1u32, 9, 33, 7];
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for &t in &tokens {
            let a = m.decode_step(&mut c1, t);
            let b = decode_step_with(&m, &lin, &mut c2, t);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    fn quantize_model_with(
        m: &Transformer,
        bits: u32,
        method: Method,
        processing: Processing,
    ) -> QuantizedModel {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut layers = Vec::new();
        for spec in m.cfg.linear_specs() {
            let wdata = m.get_weight(&spec.name).unwrap();
            let w = Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, spec.in_dim / 3, 1e-2);
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method,
                    processing: processing.clone(),
                    ..Default::default()
                },
                11,
            );
            layers.push(out.into_layer(&spec.name));
        }
        QuantizedModel {
            config: m.cfg.clone(),
            bits,
            recipe: "test".into(),
            layers,
        }
    }

    fn quantize_model(m: &Transformer, bits: u32, processing: Processing) -> QuantizedModel {
        quantize_model_with(m, bits, Method::Ldlq, processing)
    }

    #[test]
    fn quant_linears_match_dequantized_weights() {
        // The fused on-the-fly path must equal dequantize-then-f32-matvec.
        for processing in [
            Processing::baseline(),
            Processing::incoherent(),
            Processing::incoherent_with(crate::linalg::TransformKind::Hadamard),
        ] {
            let m = tiny();
            let qm = quantize_model(&m, 4, processing);
            let qlin = QuantLinears::from_model(&qm).unwrap();
            // Dequantized comparison model
            let mut md = tiny();
            qm.apply_to(&mut md).unwrap();
            let fp = FpLinears { model: &md };
            let d = m.cfg.d_model;
            let x: Vec<f32> = (0..d).map(|i| ((i as f32) * 0.37).sin()).collect();
            for blk in 0..m.cfg.n_layers {
                for slot in 0..4 {
                    let mut ya = vec![0.0f32; d];
                    let mut yb = vec![0.0f32; d];
                    qlin.apply(blk, slot, &x, &mut ya);
                    fp.apply(blk, slot, &x, &mut yb);
                    for (a, b) in ya.iter().zip(&yb) {
                        assert!(
                            (a - b).abs() < 1e-3 * b.abs().max(1.0),
                            "blk{blk} slot{slot}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quant_decode_runs_and_is_close_to_dequantized() {
        let m = tiny();
        let qm = quantize_model(&m, 4, Processing::incoherent());
        let qlin = QuantLinears::from_model(&qm).unwrap();
        let mut md = tiny();
        qm.apply_to(&mut md).unwrap();
        let fp = FpLinears { model: &md };
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for &t in &[1u32, 20, 33] {
            let a = decode_step_with(&m, &qlin, &mut c1, t);
            let b = decode_step_with(&md, &fp, &mut c2, t);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 5e-2, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn batched_kernel_matches_dequantized_dense() {
        // The fused batch kernel must match `QuantizedLayer::dequantize()`
        // + dense matmul at 2/3/4 bits and batch sizes 1 and 17 (batch
        // and rows both non-multiples of the tile), for every transform
        // backend. m=40 makes the last tile ragged; n=52 keeps 3-bit rows
        // off byte boundaries (generic decode path) and is a non-power-
        // of-two size for the Hadamard block decomposition.
        let (m, n) = (40usize, 52usize);
        let mut rng = crate::util::rng::Rng::new(21);
        let w = Mat::from_fn(m, n, |_, _| rng.uniform(-0.5, 0.5));
        let h = random_hessian(&mut rng, n, n / 4, 1e-2);
        for processing in [
            Processing::baseline(),
            Processing::incoherent(),
            Processing::incoherent_with(crate::linalg::TransformKind::Hadamard),
        ] {
            for bits in [2u32, 3, 4] {
                let out = quantize_layer(
                    &w,
                    &h,
                    &QuantConfig {
                        bits,
                        method: Method::Ldlq,
                        processing: processing.clone(),
                        ..Default::default()
                    },
                    17,
                );
                let layer = QuantizedLayer::from_codes("t", &out.codes, bits, out.post);
                let wd = layer.dequantize(); // m×n, original space, f64
                let lin = QuantLinear::new(layer);
                for batch in [1usize, 17] {
                    let xs: Vec<f32> = (0..batch * n)
                        .map(|i| ((i as f32) * 0.013).sin())
                        .collect();
                    let mut ys = vec![0.0f32; batch * m];
                    let mut s = BatchScratch::new();
                    lin.apply_batch(&xs, batch, &mut ys, &mut s);
                    for b in 0..batch {
                        for i in 0..m {
                            let mut want = 0.0f64;
                            for j in 0..n {
                                want += wd[(i, j)] * xs[b * n + j] as f64;
                            }
                            let got = ys[b * m + i] as f64;
                            assert!(
                                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                                "bits={bits} batch={batch} b={b} i={i}: {got} vs {want}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn apply_batch_matches_apply_per_query() {
        // The batched fused kernel and the single-vector fused matvec are
        // the same linear map (different summation order only).
        let m = tiny();
        let qm = quantize_model(&m, 4, Processing::incoherent());
        let qlin = QuantLinears::from_model(&qm).unwrap();
        let d = m.cfg.d_model;
        let batch = 17usize;
        let xs: Vec<f32> = (0..batch * d).map(|i| ((i as f32) * 0.11).cos()).collect();
        for blk in 0..m.cfg.n_layers {
            for slot in 0..4 {
                let mut ys = vec![0.0f32; batch * d];
                qlin.apply_batch(blk, slot, &xs, batch, &mut ys);
                for b in 0..batch {
                    let mut y1 = vec![0.0f32; d];
                    qlin.apply(blk, slot, &xs[b * d..(b + 1) * d], &mut y1);
                    for (a, e) in ys[b * d..(b + 1) * d].iter().zip(&y1) {
                        assert!(
                            (a - e).abs() < 1e-3 * e.abs().max(1.0),
                            "blk{blk} slot{slot} b{b}: {a} vs {e}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn decode_step_batch_matches_single_at_mixed_positions() {
        // Three sequences at different cache positions (continuous
        // batching shape) must decode exactly as three single steps.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prefixes: [&[u32]; 3] = [&[1, 9, 33], &[7], &[2, 4, 6, 8]];
        let mut single: Vec<KvCache> = Vec::new();
        let mut batched: Vec<KvCache> = Vec::new();
        for p in prefixes {
            let mut c1 = m.new_cache();
            let mut c2 = m.new_cache();
            for &t in p {
                decode_step_with(&m, &lin, &mut c1, t);
                decode_step_with(&m, &lin, &mut c2, t);
            }
            single.push(c1);
            batched.push(c2);
        }
        let next = [5u32, 11, 17];
        let mut expect = Vec::new();
        for (c, &t) in single.iter_mut().zip(&next) {
            expect.push(decode_step_with(&m, &lin, c, t));
        }
        let mut refs: Vec<&mut KvCache> = batched.iter_mut().collect();
        let got = decode_step_batch(&m, &lin, &mut refs, &next);
        let v = m.cfg.vocab;
        for (b, exp) in expect.iter().enumerate() {
            for (j, e) in exp.iter().enumerate() {
                let g = got[b * v + j];
                assert!((g - e).abs() < 1e-5, "seq {b} logit {j}: {g} vs {e}");
            }
        }
        // Cache positions advanced identically.
        for (c1, c2) in single.iter().zip(&batched) {
            assert_eq!(c1.len(), c2.len());
        }
    }

    #[test]
    fn paged_batch_decode_is_logit_identical_to_contig() {
        // Exact-equality pin: the block-table indirection must not change
        // the float schedule at all. Both arms prefill with identical
        // batch-1 steps, then take one batched step at batch 1 and at
        // batch 17 with ragged positions spanning page boundaries.
        let m = tiny();
        let lin = FpLinears { model: &m };
        for bsz in [1usize, 17] {
            let pool = KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 256, 4);
            let mut contig: Vec<KvCache> = Vec::new();
            let mut paged: Vec<KvCache> = Vec::new();
            for b in 0..bsz {
                let mut c1 = m.new_cache();
                let mut c2 = m.new_paged_cache(&pool);
                for j in 0..=(b % 17) {
                    let t = ((b * 31 + j * 7) % 256) as u32;
                    let a = decode_step_with(&m, &lin, &mut c1, t);
                    let p = decode_step_with(&m, &lin, &mut c2, t);
                    assert_eq!(a, p, "prefill seq {b} step {j}");
                }
                contig.push(c1);
                paged.push(c2);
            }
            let next: Vec<u32> = (0..bsz).map(|b| ((b * 13 + 5) % 256) as u32).collect();
            let mut r1: Vec<&mut KvCache> = contig.iter_mut().collect();
            let a = decode_step_batch(&m, &lin, &mut r1, &next);
            let mut r2: Vec<&mut KvCache> = paged.iter_mut().collect();
            let p = decode_step_batch(&m, &lin, &mut r2, &next);
            assert_eq!(a, p, "batched step at bsz {bsz}");
        }
    }

    #[test]
    fn paged_prefix_sharing_and_cow_are_logit_identical() {
        // Two sequences share a 10-token prompt through the prefix
        // registry (rows 0..9 shared, last token recomputed), then
        // diverge; each must stay bit-identical to a contiguous replay.
        let m = tiny();
        let lin = FpLinears { model: &m };
        let prompt: Vec<u32> = (0..10u32).map(|j| j * 11 + 3).collect();

        let pool = KvPool::shared(m.cfg.n_layers, m.cfg.d_model, 64, 16);
        // Sequence A populates the pool and registers the prompt prefix.
        let ta = pool.lock().unwrap().try_admit(&prompt, 8).unwrap();
        let mut ca = KvCache::paged(&pool, ta);
        let mut last_a = Vec::new();
        for &t in &prompt {
            last_a = decode_step_with(&m, &lin, &mut ca, t);
        }

        // Sequence B admits the same prompt: shares rows 0..9 and COWs
        // the shared tail page on its first write.
        let tb = pool.lock().unwrap().try_admit(&prompt, 8).unwrap();
        let shared = tb.len();
        assert_eq!(shared, prompt.len() - 1, "max share leaves the last token");
        let mut cb = KvCache::paged(&pool, tb);
        let mut last_b = Vec::new();
        for &t in &prompt[shared..] {
            last_b = decode_step_with(&m, &lin, &mut cb, t);
        }
        assert_eq!(last_a, last_b, "shared-prefix decode of last prompt token");

        // Diverge, and pin each arm to its own contiguous replay.
        let a1 = decode_step_with(&m, &lin, &mut ca, 100);
        let b1 = decode_step_with(&m, &lin, &mut cb, 200);
        let mut ref_a = m.new_cache();
        let mut ref_b = m.new_cache();
        for &t in &prompt {
            decode_step_with(&m, &lin, &mut ref_a, t);
            decode_step_with(&m, &lin, &mut ref_b, t);
        }
        assert_eq!(decode_step_with(&m, &lin, &mut ref_a, 100), a1);
        assert_eq!(decode_step_with(&m, &lin, &mut ref_b, 200), b1);

        let g = pool.lock().unwrap();
        assert!(g.stats.prefix_hits >= 1, "B's admit must hit the registry");
        assert!(g.stats.cow_copies >= 1, "B must COW the shared tail page");
        assert_eq!(g.stats.prefix_tokens_shared, (prompt.len() - 1) as u64);
    }

    #[test]
    fn decode_step_batch_quantized_close_to_single() {
        let m = tiny();
        let qm = quantize_model(&m, 4, Processing::incoherent());
        let qlin = QuantLinears::from_model(&qm).unwrap();
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for &t in &[3u32, 8] {
            decode_step_with(&m, &qlin, &mut c1, t);
            decode_step_with(&m, &qlin, &mut c2, t);
        }
        let a = decode_step_with(&m, &qlin, &mut c1, 20);
        let mut refs: Vec<&mut KvCache> = vec![&mut c2];
        let b = decode_step_batch(&m, &qlin, &mut refs, &[20]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 5e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn hadamard_decode_close_to_dequantized() {
        // End-to-end decode with the RHT backend matches its dequantized
        // reference model, just like the Kron path above.
        let m = tiny();
        let qm = quantize_model(
            &m,
            4,
            Processing::incoherent_with(crate::linalg::TransformKind::Hadamard),
        );
        for l in &qm.layers {
            assert_eq!(l.post.transform, crate::linalg::TransformKind::Hadamard);
        }
        let qlin = QuantLinears::from_model(&qm).unwrap();
        let mut md = tiny();
        qm.apply_to(&mut md).unwrap();
        let fp = FpLinears { model: &md };
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for &t in &[1u32, 20, 33] {
            let a = decode_step_with(&m, &qlin, &mut c1, t);
            let b = decode_step_with(&md, &fp, &mut c2, t);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 5e-2, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn vq_fused_decode_matches_dequantized_through_v3_artifact() {
        // Acceptance: quantize with the vq rounder → save a v3 container
        // → load → the fused LUT decode (single-vector and batched at
        // batch {1, 17}) equals the dequantized dense reference.
        for bits in [2u32, 4] {
            let m = tiny();
            let qm = quantize_model_with(&m, bits, Method::Vq, Processing::incoherent());
            let bytes = qm.to_bytes(crate::model::quantized::QZ_VERSION);
            let loaded = QuantizedModel::from_bytes(&bytes).unwrap();
            let qlin = QuantLinears::from_model(&loaded).unwrap();
            let mut md = tiny();
            loaded.apply_to(&mut md).unwrap();
            let fp = FpLinears { model: &md };
            let d = m.cfg.d_model;
            for blk in 0..m.cfg.n_layers {
                for slot in 0..4 {
                    for batch in [1usize, 17] {
                        let xs: Vec<f32> =
                            (0..batch * d).map(|i| ((i as f32) * 0.053).sin()).collect();
                        let mut ya = vec![0.0f32; batch * d];
                        let mut yb = vec![0.0f32; batch * d];
                        qlin.apply_batch(blk, slot, &xs, batch, &mut ya);
                        fp.apply_batch(blk, slot, &xs, batch, &mut yb);
                        for (a, b) in ya.iter().zip(&yb) {
                            assert!(
                                (a - b).abs() < 1e-3 * b.abs().max(1.0),
                                "bits={bits} blk{blk} slot{slot} batch{batch}: {a} vs {b}"
                            );
                        }
                        // Single-vector fused path agrees with the batch.
                        if batch == 1 {
                            let mut y1 = vec![0.0f32; d];
                            qlin.apply(blk, slot, &xs, &mut y1);
                            for (a, b) in y1.iter().zip(&ya) {
                                assert!((a - b).abs() < 1e-3 * b.abs().max(1.0));
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn vq_layer_kernel_matches_dense_at_ragged_sizes() {
        // A single vq QuantLinear at m=40, n=52 (ragged last tile AND a
        // ragged last 8-group) against dequantize + dense matmul, for
        // both transform backends.
        let (m, n) = (40usize, 52usize);
        let mut rng = crate::util::rng::Rng::new(21);
        let w = Mat::from_fn(m, n, |_, _| rng.uniform(-0.5, 0.5));
        let h = random_hessian(&mut rng, n, n / 4, 1e-2);
        for processing in [
            Processing::incoherent(),
            Processing::incoherent_with(crate::linalg::TransformKind::Hadamard),
        ] {
            for bits in [2u32, 4] {
                let out = quantize_layer(
                    &w,
                    &h,
                    &QuantConfig {
                        bits,
                        method: Method::Vq,
                        processing: processing.clone(),
                        ..Default::default()
                    },
                    17,
                );
                let vq = out.vq.as_ref().expect("vq indices");
                let layer = crate::quant::packed::QuantizedLayer::from_vq_indices(
                    "t", m, n, bits, vq, out.post,
                );
                let wd = layer.dequantize();
                let lin = QuantLinear::new(layer);
                for batch in [1usize, 17] {
                    let xs: Vec<f32> = (0..batch * n)
                        .map(|i| ((i as f32) * 0.013).sin())
                        .collect();
                    let mut ys = vec![0.0f32; batch * m];
                    let mut s = BatchScratch::new();
                    lin.apply_batch(&xs, batch, &mut ys, &mut s);
                    for b in 0..batch {
                        for i in 0..m {
                            let mut want = 0.0f64;
                            for j in 0..n {
                                want += wd[(i, j)] * xs[b * n + j] as f64;
                            }
                            let got = ys[b * m + i] as f64;
                            assert!(
                                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                                "bits={bits} batch={batch} b={b} i={i}: {got} vs {want}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn vq_decode_step_close_to_dequantized() {
        // Full decode loop over a vq artifact stays close to the
        // dequantized fp32 reference — serving works unchanged.
        let m = tiny();
        let qm = quantize_model_with(&m, 4, Method::Vq, Processing::incoherent());
        let qlin = QuantLinears::from_model(&qm).unwrap();
        let mut md = tiny();
        qm.apply_to(&mut md).unwrap();
        let fp = FpLinears { model: &md };
        let mut c1 = m.new_cache();
        let mut c2 = m.new_cache();
        for &t in &[1u32, 20, 33] {
            let a = decode_step_with(&m, &qlin, &mut c1, t);
            let b = decode_step_with(&md, &fp, &mut c2, t);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 5e-2, "{x} vs {y}");
            }
        }
    }
}
