//! Inference engines.
//!
//! * [`native`] — the pure-Rust hot path: fused unpack-dequant matvec with
//!   QuIP's fast Kronecker incoherence transform, pluggable into a generic
//!   decode step (this is what Table 4's throughput comparison measures).
//! * [`pjrt_engine`] — executes the AOT JAX/Pallas artifacts through the
//!   PJRT runtime for batched prefill/scoring; proves the three layers
//!   compose (Python authored the graph once; Rust runs it).

pub mod native;
pub mod pjrt_engine;

pub use native::{decode_step_batch, decode_step_with, FpLinears, LinearOps, QuantLinears};
pub use pjrt_engine::PjrtLm;
