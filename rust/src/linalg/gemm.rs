//! Blocked, threaded GEMM for f64 (`Mat`) and f32 slices (model hot path).
//!
//! Strategy: pack nothing, tile over (i, k, j) with a transposed-B inner
//! kernel when profitable, parallelize over row blocks with scoped threads.
//! This is the L3 performance substrate — see EXPERIMENTS.md §Perf for the
//! measured speedup over the naive loop.

use super::matrix::Mat;
use crate::util::threadpool::{default_threads, parallel_chunks, parallel_for};

const BLOCK: usize = 64;

/// C = A · B, blocked and threaded.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul dims {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let threads = if m * n * k > 64 * 64 * 64 {
        default_threads()
    } else {
        1
    };
    // §Perf iteration 1: on a single hardware thread the k-blocked variant
    // re-streams the output matrix per k-block and loses ~2× to the plain
    // row-major saxpy kernel; use the latter whenever there is no
    // parallelism to exploit (measured: 512³ f64, 72ms → 43ms).
    if threads == 1 {
        return a.matmul_naive(b);
    }
    let mut out = Mat::zeros(m, n);
    let n_row_blocks = m.div_ceil(BLOCK);
    // Each task owns a disjoint row block of the output; no locking needed.
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    parallel_for(n_row_blocks, threads, |bi| {
        let i0 = bi * BLOCK;
        let i1 = (i0 + BLOCK).min(m);
        let out_ptr = &out_ptr;
        // SAFETY: row blocks [i0, i1) are disjoint across tasks.
        let c = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i0 * n), (i1 - i0) * n) };
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a.row(i)[k0..k1];
                let crow = &mut c[(i - i0) * n..(i - i0 + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(k0 + kk);
                    // saxpy: crow += av * brow
                    let mut j = 0;
                    while j + 4 <= n {
                        crow[j] += av * brow[j];
                        crow[j + 1] += av * brow[j + 1];
                        crow[j + 2] += av * brow[j + 2];
                        crow[j + 3] += av * brow[j + 3];
                        j += 4;
                    }
                    while j < n {
                        crow[j] += av * brow[j];
                        j += 1;
                    }
                }
            }
        }
    });
    out
}

struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// C = A · Bᵀ without materializing Bᵀ (both row-major, dot-product kernel).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let (m, n) = (a.rows, b.rows);
    let mut out = Mat::zeros(m, n);
    let threads = if m * n * a.cols > 64 * 64 * 64 {
        default_threads()
    } else {
        1
    };
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    parallel_for(m, threads, |i| {
        let out_ptr = &out_ptr;
        // SAFETY: each task writes only row i.
        let crow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
        let arow = a.row(i);
        for j in 0..n {
            crow[j] = super::matrix::dot(arow, b.row(j));
        }
    });
    out
}

/// Accumulate the rank-k update AᵀA into the **upper triangle** of `out`
/// (n×n): `out[i][j] += Σ_t a[t·n+i]·a[t·n+j]` for j ≥ i. Blocked over
/// output row blocks and threaded like [`matmul`]; the per-entry reduction
/// runs over t in ascending order regardless of thread count, so results
/// are bit-deterministic (EXPERIMENTS.md §Perf 4). This is the substrate
/// of [`syrk`]/[`gram`] and of the panel flush in
/// [`crate::hessian::HessianAccum`].
pub fn syrk_acc_upper(r: usize, n: usize, a: &[f64], out: &mut Mat) {
    assert_eq!(a.len(), r * n, "syrk panel is {r}×{n}");
    assert_eq!((out.rows, out.cols), (n, n), "syrk output must be {n}×{n}");
    if r == 0 || n == 0 {
        return;
    }
    let threads = if r * n * n > 2 * 64 * 64 * 64 {
        default_threads()
    } else {
        1
    };
    let n_row_blocks = n.div_ceil(BLOCK);
    // Each task owns output rows [i0, i1); writes never alias.
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    parallel_for(n_row_blocks, threads, |bi| {
        let i0 = bi * BLOCK;
        let i1 = (i0 + BLOCK).min(n);
        let out_ptr = &out_ptr;
        // SAFETY: row blocks [i0, i1) are disjoint across tasks.
        let block =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i0 * n), (i1 - i0) * n) };
        for t in 0..r {
            let x = &a[t * n..(t + 1) * n];
            for i in i0..i1 {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                let orow = &mut block[(i - i0) * n..(i - i0 + 1) * n];
                // saxpy over the row's upper-triangle tail.
                for j in i..n {
                    orow[j] += xi * x[j];
                }
            }
        }
    });
}

/// Mirror the upper triangle of a square matrix into the lower — the
/// finalize step of [`syrk`] and of `HessianAccum::finish`.
pub fn mirror_upper(m: &mut Mat) {
    assert_eq!(m.rows, m.cols);
    for i in 0..m.rows {
        for j in 0..i {
            m[(i, j)] = m[(j, i)];
        }
    }
}

/// C = AᵀA, symmetric: blocked threaded rank-k update over the upper
/// triangle ([`syrk_acc_upper`]), mirrored once at the end.
pub fn syrk(a: &Mat) -> Mat {
    let mut out = Mat::zeros(a.cols, a.cols);
    syrk_acc_upper(a.rows, a.cols, &a.data, &mut out);
    mirror_upper(&mut out);
    out
}

/// C = Aᵀ · A (Gram matrix). Thin wrapper over [`syrk`], kept under the
/// established name for Hessian-collection call sites.
pub fn gram(a: &Mat) -> Mat {
    syrk(a)
}

/// Apply `f(i, row_i)` to rows [r0, r1) of `m` in parallel; each task
/// mutates only its own row, so writes never alias. Substrate for the
/// panel solves in the blocked LDL/Cholesky factorizations.
pub(crate) fn par_rows<F>(m: &mut Mat, r0: usize, r1: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    if r1 <= r0 {
        return;
    }
    let cols = m.cols;
    let ptr = SendPtr(m.data.as_mut_ptr());
    parallel_for(r1 - r0, threads, |li| {
        let i = r0 + li;
        let ptr = &ptr;
        // SAFETY: each task touches only row i; rows are disjoint.
        let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * cols), cols) };
        f(i, row);
    });
}

/// Symmetric trailing downdate of blocked LDL/Cholesky:
/// `a[i][j] −= pd_row(i) · p_row(j)` for rows i in [r0, n) and columns
/// r0 ≤ j ≤ i (lower triangle only), with `p`/`pd` the packed panel
/// `(n−r0)×w` (for LDL, `pd` is the panel scaled by the block pivots; for
/// Cholesky pass the panel twice). Threaded over row blocks; the
/// per-entry dot has a fixed reduction order, so results do not depend on
/// the thread count.
pub(crate) fn trailing_downdate_lower(a: &mut Mat, r0: usize, pd: &[f64], p: &[f64], w: usize) {
    let n = a.rows;
    assert_eq!(a.cols, n);
    let rows_t = n - r0;
    if rows_t == 0 || w == 0 {
        return;
    }
    assert_eq!(p.len(), rows_t * w);
    assert_eq!(pd.len(), rows_t * w);
    let threads = if rows_t * rows_t / 2 * w > 64 * 64 * 64 {
        default_threads()
    } else {
        1
    };
    let ptr = SendPtr(a.data.as_mut_ptr());
    parallel_for(rows_t.div_ceil(BLOCK), threads, |bi| {
        let lo = r0 + bi * BLOCK;
        let hi = (lo + BLOCK).min(n);
        let ptr = &ptr;
        for i in lo..hi {
            // SAFETY: each task owns rows [lo, hi) of `a`; disjoint.
            let row = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * n), n) };
            let pdi = &pd[(i - r0) * w..(i - r0 + 1) * w];
            for j in r0..=i {
                row[j] -= super::matrix::dot(pdi, &p[(j - r0) * w..(j - r0 + 1) * w]);
            }
        }
    });
}

// ----------------------------------------------------------------------
// f32 kernels for the model / inference engine hot path.
// ----------------------------------------------------------------------

/// out[m×n] = a[m×k] · b[k×n], all row-major f32 slices. Threaded over rows.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let threads = if m * n * k > 32 * 32 * 32 {
        default_threads()
    } else {
        1
    };
    let out_ptr = SendPtrF32(out.as_mut_ptr());
    parallel_for(m, threads, |i| {
        let out_ptr = &out_ptr;
        // SAFETY: each task writes only row i.
        let crow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let mut j = 0;
            while j + 8 <= n {
                crow[j] += av * brow[j];
                crow[j + 1] += av * brow[j + 1];
                crow[j + 2] += av * brow[j + 2];
                crow[j + 3] += av * brow[j + 3];
                crow[j + 4] += av * brow[j + 4];
                crow[j + 5] += av * brow[j + 5];
                crow[j + 6] += av * brow[j + 6];
                crow[j + 7] += av * brow[j + 7];
                j += 8;
            }
            while j < n {
                crow[j] += av * brow[j];
                j += 1;
            }
        }
    });
}

struct SendPtrF32(*mut f32);
unsafe impl Send for SendPtrF32 {}
unsafe impl Sync for SendPtrF32 {}

/// out[b×m] = a[b×k] · W(tile)ᵀ where W is produced tile-by-tile by
/// `decode`: for each row tile [i0, i1) of the (m×k) weight matrix,
/// `decode(i0, i1, buf)` fills `buf` ((i1−i0)×k row-major) with that
/// tile's weights. The decode cost is paid once per tile and amortized
/// over all `b` query rows — this is the substrate of the fused
/// packed-weight batch kernel in `engine::native`. Tiles are parallelized
/// over the worker threads; each tile owns a disjoint output column range.
pub fn sgemm_bt_fused<F>(
    b: usize,
    k: usize,
    m: usize,
    tile_rows: usize,
    a: &[f32],
    decode: &F,
    out: &mut [f32],
) where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(a.len(), b * k);
    assert_eq!(out.len(), b * m);
    if b == 0 || m == 0 {
        return;
    }
    let tile_rows = tile_rows.max(1);
    let n_tiles = m.div_ceil(tile_rows);
    let threads = if b * m * k > 32 * 32 * 32 {
        default_threads()
    } else {
        1
    };
    let out_ptr = SendPtrF32(out.as_mut_ptr());
    // Chunk tiles so each task allocates its tile buffer once and reuses
    // it (a few chunks per thread for load balance; this runs once per
    // linear per token step, so per-tile allocation would add up fast).
    let chunk = n_tiles.div_ceil(threads * 4).max(1);
    parallel_chunks(n_tiles, threads, chunk, |t0, t1| {
        let mut wt = vec![0.0f32; tile_rows * k];
        let out_ptr = &out_ptr;
        for t in t0..t1 {
            let i0 = t * tile_rows;
            let i1 = (i0 + tile_rows).min(m);
            let buf = &mut wt[..(i1 - i0) * k];
            decode(i0, i1, buf);
            for bi in 0..b {
                let arow = &a[bi * k..(bi + 1) * k];
                for i in i0..i1 {
                    let v = sdot(arow, &buf[(i - i0) * k..(i - i0 + 1) * k]);
                    // SAFETY: tile t exclusively owns columns [i0, i1) of
                    // every output row; writes from distinct tasks (and
                    // distinct tiles) never alias.
                    unsafe { *out_ptr.0.add(bi * m + i) = v };
                }
            }
        }
    });
}

/// out[m×n] = a[m×k] · b[n×k]ᵀ — B stored transposed (weight layout:
/// each output feature's weights contiguous), the natural layout for
/// matvec-heavy decode.
pub fn sgemm_bt(m: usize, k: usize, n: usize, a: &[f32], bt: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    assert_eq!(out.len(), m * n);
    let threads = if m * n * k > 32 * 32 * 32 {
        default_threads()
    } else {
        1
    };
    let out_ptr = SendPtrF32(out.as_mut_ptr());
    parallel_for(m, threads, |i| {
        let out_ptr = &out_ptr;
        let crow = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n) };
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            crow[j] = sdot(arow, &bt[j * k..(j + 1) * k]);
        }
    });
}

#[inline]
pub fn sdot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.uniform(-1.0, 1.0))
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(3, 4, 5), (17, 33, 9), (65, 70, 66), (128, 100, 130)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let fast = matmul(&a, &b);
            let slow = a.matmul_naive(&b);
            assert!(
                super::super::matrix::max_abs_diff(&fast, &slow) < 1e-9,
                "mismatch at {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 31, 17);
        let b = random_mat(&mut rng, 23, 17);
        let fast = matmul_bt(&a, &b);
        let slow = a.matmul_naive(&b.transpose());
        assert!(super::super::matrix::max_abs_diff(&fast, &slow) < 1e-9);
    }

    #[test]
    fn gram_matches() {
        let mut rng = Rng::new(3);
        let a = random_mat(&mut rng, 40, 12);
        let g = gram(&a);
        let slow = a.transpose().matmul_naive(&a);
        assert!(super::super::matrix::max_abs_diff(&g, &slow) < 1e-9);
    }

    #[test]
    fn syrk_matches_naive_at_ragged_sizes() {
        // Sizes straddle the 64-wide block boundary (1, 7, 33, 130) so the
        // partial-block paths and the threaded multi-block path both run.
        let mut rng = Rng::new(30);
        for &n in &[1usize, 7, 33, 130] {
            for &r in &[1usize, 5, 130] {
                let a = random_mat(&mut rng, r, n);
                let fast = syrk(&a);
                let slow = a.transpose().matmul_naive(&a);
                assert!(
                    super::super::matrix::max_abs_diff(&fast, &slow) < 1e-9,
                    "r={r} n={n}"
                );
                // Exactly symmetric (mirror, not recompute).
                for i in 0..n {
                    for j in 0..i {
                        assert_eq!(fast[(i, j)], fast[(j, i)]);
                    }
                }
            }
        }
    }

    #[test]
    fn syrk_acc_accumulates_on_top() {
        // Two panel flushes must equal one combined flush bit for bit:
        // the reduction order per entry is t-ascending either way.
        let mut rng = Rng::new(31);
        let n = 33;
        let a = random_mat(&mut rng, 20, n);
        let mut two = Mat::zeros(n, n);
        syrk_acc_upper(8, n, &a.data[..8 * n], &mut two);
        syrk_acc_upper(12, n, &a.data[8 * n..], &mut two);
        let mut one = Mat::zeros(n, n);
        syrk_acc_upper(20, n, &a.data, &mut one);
        assert_eq!(one.data, two.data);
    }

    #[test]
    fn trailing_downdate_matches_reference() {
        let mut rng = Rng::new(32);
        let n = 90;
        let r0 = 20;
        let w = 13;
        let mut a = random_mat(&mut rng, n, n);
        let reference = a.clone();
        let p: Vec<f64> = (0..(n - r0) * w).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let pd: Vec<f64> = p.iter().map(|x| x * 1.5).collect();
        trailing_downdate_lower(&mut a, r0, &pd, &p, w);
        for i in 0..n {
            for j in 0..n {
                if i >= r0 && j >= r0 && j <= i {
                    let mut s = 0.0;
                    for k in 0..w {
                        s += pd[(i - r0) * w + k] * p[(j - r0) * w + k];
                    }
                    assert!((a[(i, j)] - (reference[(i, j)] - s)).abs() < 1e-12);
                } else {
                    assert_eq!(a[(i, j)], reference[(i, j)], "untouched ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sgemm_matches_f64() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (9, 33, 21);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut out = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut out);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                assert!((out[i * n + j] as f64 - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn sgemm_bt_fused_matches_sgemm_bt() {
        let mut rng = Rng::new(6);
        // Ragged shapes: batch not a tile multiple, m not a tile multiple.
        let shapes = [(1usize, 24usize, 40usize, 16usize), (17, 33, 50, 16), (5, 8, 3, 64)];
        for &(b, k, m, tile) in &shapes {
            let a: Vec<f32> = (0..b * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let w: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let mut o1 = vec![0.0f32; b * m];
            let mut o2 = vec![0.0f32; b * m];
            sgemm_bt(b, k, m, &a, &w, &mut o1);
            sgemm_bt_fused(
                b,
                k,
                m,
                tile,
                &a,
                &|i0: usize, i1: usize, buf: &mut [f32]| {
                    buf.copy_from_slice(&w[i0 * k..i1 * k]);
                },
                &mut o2,
            );
            for (x, y) in o1.iter().zip(&o2) {
                assert_eq!(x, y, "b={b} k={k} m={m} tile={tile}");
            }
        }
    }

    #[test]
    fn sgemm_bt_matches_sgemm() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (7, 19, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        // bt[j*k + kk] = b[kk*n + j]
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut o1 = vec![0.0f32; m * n];
        let mut o2 = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut o1);
        sgemm_bt(m, k, n, &a, &bt, &mut o2);
        for (x, y) in o1.iter().zip(&o2) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
