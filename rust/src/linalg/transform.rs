//! The incoherence-transform subsystem: a pluggable family of seeded fast
//! orthogonal operators used to conjugate W and H (QuIP §4.1–4.2).
//!
//! Every backend is a [`Transform`]: an orthogonal operator V on ℝⁿ that is
//! (a) regenerated exactly from a 64-bit seed — artifacts store only
//! `(kind, seed)`, never the matrix — and (b) applicable in o(n²) to
//! vectors, matrix rows/columns, and f32 inference activations. Two
//! backends ship:
//!
//! * [`TransformKind::Kron`] — the paper's two-factor Kronecker operator
//!   `(L ⊗ R)·P` with Haar-orthogonal factors ([`super::kron`]),
//!   O(n(p+q)) per apply.
//! * [`TransformKind::Hadamard`] — the randomized Hadamard transform of
//!   QuIP# (Tseng et al., 2024): `B·D·P` with B a (block) fast
//!   Walsh–Hadamard butterfly, D a random ±1 diagonal and P a random
//!   permutation ([`super::hadamard`]), O(n log n) per apply with strictly
//!   better incoherence concentration.
//!
//! "No transform" is not a kind: `Processing::incoherent == false` (and
//! `PostState::incoherent == false`) means the conjugation step is skipped
//! entirely, which is what the CLI's `--transform none` sets.

use super::matrix::Mat;
use std::sync::Arc;

/// Which incoherence-transform backend generated (or should generate) the
/// operator. Serialized by [`TransformKind::as_u8`] into `.qz` v2 layer
/// records; v1 artifacts predate the enum and are implicitly `Kron`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformKind {
    /// Two-factor Kronecker orthogonal (QuIP §4.2).
    Kron,
    /// Randomized (block) fast Walsh–Hadamard transform (QuIP#).
    Hadamard,
}

impl TransformKind {
    /// Wire code for artifact serialization (stable across versions).
    pub fn as_u8(self) -> u8 {
        match self {
            TransformKind::Kron => 0,
            TransformKind::Hadamard => 1,
        }
    }

    /// Inverse of [`as_u8`](Self::as_u8); errors on unknown codes so a
    /// corrupt artifact fails loudly instead of decoding garbage.
    pub fn from_u8(code: u8) -> crate::Result<TransformKind> {
        Ok(match code {
            0 => TransformKind::Kron,
            1 => TransformKind::Hadamard,
            other => anyhow::bail!("unknown transform kind code {other}"),
        })
    }

    /// Parse a CLI name. `none` is not a kind (it disables the
    /// incoherence step) and is rejected here — callers handle it before
    /// parsing.
    pub fn parse(s: &str) -> crate::Result<TransformKind> {
        Ok(match s {
            "kron" | "kronecker" => TransformKind::Kron,
            "hadamard" | "rht" => TransformKind::Hadamard,
            other => anyhow::bail!(
                "unknown transform '{other}' (expected kron, hadamard or none)"
            ),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransformKind::Kron => "kron",
            TransformKind::Hadamard => "hadamard",
        }
    }
}

impl std::fmt::Display for TransformKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A seeded fast orthogonal operator V on ℝⁿ. Object-safe: the engine and
/// the quantizer hold `Arc<dyn Transform>` and never know the backend.
///
/// Orthogonality is the contract: `inverse_*` must apply Vᵀ = V⁻¹, so
/// `inverse(forward(x)) == x` to rounding error, and conjugation preserves
/// the proxy quadratic form tr(ΔHΔᵀ).
///
/// The f32 methods are the inference hot path: they must not allocate.
/// `scratch` is caller-provided with `len >= self.n()`; `x` and `y` must
/// not alias.
pub trait Transform: Send + Sync {
    fn kind(&self) -> TransformKind;
    fn n(&self) -> usize;
    fn seed(&self) -> u64;

    /// y = V x.
    fn forward_vec(&self, x: &[f64]) -> Vec<f64>;
    /// x = Vᵀ y.
    fn inverse_vec(&self, y: &[f64]) -> Vec<f64>;
    /// V M (M is n×c).
    fn forward_mat_left(&self, m: &Mat) -> Mat;
    /// Vᵀ M (M is n×c).
    fn inverse_mat_left(&self, m: &Mat) -> Mat;

    /// y = V x in f32 (fused inference apply).
    fn forward_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]);
    /// y = Vᵀ x in f32.
    fn inverse_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]);

    /// M Vᵀ (M is c×n).
    fn forward_mat_right_t(&self, m: &Mat) -> Mat {
        self.forward_mat_left(&m.transpose()).transpose()
    }

    /// M V (M is c×n).
    fn inverse_mat_right(&self, m: &Mat) -> Mat {
        self.inverse_mat_left(&m.transpose()).transpose()
    }

    /// V H Vᵀ (conjugation; H n×n).
    fn conj_sym(&self, h: &Mat) -> Mat {
        let vh = self.forward_mat_left(h);
        self.forward_mat_left(&vh.transpose()).transpose()
    }

    /// Vᵀ H V.
    fn conj_sym_t(&self, h: &Mat) -> Mat {
        let vth = self.inverse_mat_left(h);
        self.inverse_mat_left(&vth.transpose()).transpose()
    }

    /// Materialize V as a dense n×n matrix (tests / diagnostics only).
    fn dense(&self) -> Mat {
        let n = self.n();
        let mut v = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.forward_vec(&e);
            v.set_col(j, &col);
            e[j] = 0.0;
        }
        v
    }
}

/// Construct a transform backend from its seed. The same
/// `(kind, seed, n, permute)` always regenerates the same operator — this
/// is what makes storing only `(kind, seed)` in artifacts possible.
pub fn make_transform(
    kind: TransformKind,
    seed: u64,
    n: usize,
    permute: bool,
) -> Arc<dyn Transform> {
    match kind {
        TransformKind::Kron => {
            Arc::new(super::kron::KronTransform::from_seed_with(seed, n, permute))
        }
        TransformKind::Hadamard => {
            Arc::new(super::hadamard::RandomizedHadamard::from_seed_with(seed, n, permute))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;

    #[test]
    fn kind_codes_roundtrip() {
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            assert_eq!(TransformKind::from_u8(kind.as_u8()).unwrap(), kind);
            assert_eq!(TransformKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(TransformKind::from_u8(9).is_err());
        assert!(TransformKind::parse("none").is_err());
        assert!(TransformKind::parse("dct").is_err());
        assert_eq!(TransformKind::parse("rht").unwrap(), TransformKind::Hadamard);
        assert_eq!(TransformKind::parse("kronecker").unwrap(), TransformKind::Kron);
    }

    #[test]
    fn every_backend_is_orthogonal_and_involutive() {
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            for n in [6usize, 12, 13, 16, 24] {
                let t = make_transform(kind, 11, n, true);
                assert_eq!(t.kind(), kind);
                assert_eq!(t.n(), n);
                let v = t.dense();
                let vtv = v.transpose().matmul_naive(&v);
                assert!(
                    max_abs_diff(&vtv, &Mat::eye(n)) < 1e-9,
                    "{kind} n={n} not orthogonal"
                );
                let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
                let back = t.inverse_vec(&t.forward_vec(&x));
                for (a, b) in back.iter().zip(&x) {
                    assert!((a - b).abs() < 1e-10, "{kind} n={n}");
                }
            }
        }
    }

    #[test]
    fn conjugation_preserves_trace_for_both_backends() {
        let mut rng = crate::util::rng::Rng::new(4);
        let h = crate::util::testkit::random_spd(&mut rng, 12, 1e-3);
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            let t = make_transform(kind, 7, 12, true);
            let hc = t.conj_sym(&h);
            assert!((hc.trace() - h.trace()).abs() < 1e-8, "{kind}");
            let back = t.conj_sym_t(&hc);
            assert!(max_abs_diff(&back, &h) < 1e-8, "{kind}");
        }
    }

    #[test]
    fn mat_side_defaults_match_dense_for_both_backends() {
        for kind in [TransformKind::Kron, TransformKind::Hadamard] {
            let n = 12;
            let t = make_transform(kind, 5, n, true);
            let d = t.dense();
            let m = Mat::from_fn(4, n, |i, j| ((i * n + j) as f64 * 0.13).cos());
            let fast = t.forward_mat_right_t(&m);
            let dense = m.matmul_naive(&d.transpose());
            assert!(max_abs_diff(&fast, &dense) < 1e-9, "{kind} MVᵀ");
            let fast2 = t.inverse_mat_right(&m);
            let dense2 = m.matmul_naive(&d);
            assert!(max_abs_diff(&fast2, &dense2) < 1e-9, "{kind} MV");
        }
    }
}
