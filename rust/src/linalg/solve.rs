//! Triangular solves and triangular inverses.

use super::matrix::Mat;

/// Solve L y = b with L lower triangular. `unit` treats diag as 1.
pub fn forward_sub(l: &Mat, b: &[f64], unit: bool) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for j in 0..i {
            s -= row[j] * y[j];
        }
        y[i] = if unit { s } else { s / row[i] };
    }
    y
}

/// Solve Lᵀ x = y with L lower triangular (so Lᵀ is upper). `unit` as above.
pub fn backward_sub_t(l: &Mat, y: &[f64], unit: bool) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in (i + 1)..n {
            s -= l[(j, i)] * x[j];
        }
        x[i] = if unit { s } else { s / l[(i, i)] };
    }
    x
}

/// Solve U x = b with U upper triangular. `unit` treats diag as 1.
pub fn backward_sub(u: &Mat, b: &[f64], unit: bool) -> Vec<f64> {
    let n = u.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        let row = u.row(i);
        for j in (i + 1)..n {
            s -= row[j] * x[j];
        }
        x[i] = if unit { s } else { s / row[i] };
    }
    x
}

/// Inverse of a *unit upper* triangular matrix (exact back-substitution;
/// the inverse is again unit upper triangular). Needed by Alg 5's
/// `U̇ = R⁻¹ − I`.
pub fn unit_upper_inverse(u: &Mat) -> Mat {
    let n = u.rows;
    let mut inv = Mat::eye(n);
    // Solve U · X = I column by column.
    for c in 0..n {
        for i in (0..=c).rev() {
            let mut s = if i == c { 1.0 } else { 0.0 };
            for j in (i + 1)..=c {
                s -= u[(i, j)] * inv[(j, c)];
            }
            inv[(i, c)] = s;
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;

    fn random_unit_upper(rng: &mut Rng, n: usize) -> Mat {
        let mut u = Mat::eye(n);
        for i in 0..n {
            for j in (i + 1)..n {
                u[(i, j)] = rng.uniform(-0.5, 0.5);
            }
        }
        u
    }

    #[test]
    fn forward_backward_roundtrip() {
        let mut rng = Rng::new(30);
        let n = 10;
        let mut l = Mat::eye(n);
        for i in 0..n {
            for j in 0..i {
                l[(i, j)] = rng.uniform(-1.0, 1.0);
            }
            l[(i, i)] = rng.uniform(0.5, 2.0);
        }
        let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = l.matvec(&x);
        let y = forward_sub(&l, &b, false);
        for (a, b) in y.iter().zip(&x) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn backward_sub_solves_upper() {
        let mut rng = Rng::new(31);
        let u = random_unit_upper(&mut rng, 12);
        let x: Vec<f64> = (0..12).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b = u.matvec(&x);
        let got = backward_sub(&u, &b, true);
        for (a, b) in got.iter().zip(&x) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn unit_upper_inverse_is_inverse() {
        let mut rng = Rng::new(32);
        for n in [1, 2, 7, 20] {
            let u = random_unit_upper(&mut rng, n);
            let inv = unit_upper_inverse(&u);
            assert!(max_abs_diff(&u.matmul_naive(&inv), &Mat::eye(n)) < 1e-9);
            // inverse is unit upper triangular
            for i in 0..n {
                assert!((inv[(i, i)] - 1.0).abs() < 1e-12);
                for j in 0..i {
                    assert_eq!(inv[(i, j)], 0.0);
                }
            }
        }
    }
}
