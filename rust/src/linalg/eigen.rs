//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used for Fig 1 / Fig 3 / Table 6 (spectra and eigenvector incoherence of
//! collected Hessians) and for tr(H^{1/2}) in the Lemma-2 bound checks.
//! O(n³) per sweep; converges in ~log(n) sweeps for our sizes (n ≤ ~1k).

use super::matrix::Mat;

/// Eigendecomposition H = Q Λ Qᵀ of a symmetric matrix.
pub struct Eigen {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Columns are the corresponding eigenvectors.
    pub vectors: Mat,
}

/// Cyclic Jacobi. `tol` is relative to the Frobenius norm; 1e-12 is a good
/// default.
pub fn eigen_sym(h: &Mat, tol: f64, max_sweeps: usize) -> Eigen {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut a = h.symmetrize();
    let mut q = Mat::eye(n);
    let fnorm = a.frob_norm().max(1e-300);

    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() <= tol * fnorm {
            break;
        }
        for p in 0..n {
            for qq in (p + 1)..n {
                let apq = a[(p, qq)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(qq, qq)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A ← Jᵀ A J on rows/cols p, q.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, qq)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, qq)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(qq, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(qq, k)] = s * apk + c * aqk;
                }
                // Accumulate Q ← Q J.
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkq = q[(k, qq)];
                    q[(k, p)] = c * qkp - s * qkq;
                    q[(k, qq)] = s * qkp + c * qkq;
                }
            }
        }
    }

    // Sort ascending by eigenvalue, permuting eigenvector columns.
    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    idx.sort_by(|&x, &y| diag[x].partial_cmp(&diag[y]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let vectors = {
        let mut v = Mat::zeros(n, n);
        for (newj, &oldj) in idx.iter().enumerate() {
            for i in 0..n {
                v[(i, newj)] = q[(i, oldj)];
            }
        }
        v
    };
    Eigen { values, vectors }
}

impl Eigen {
    /// tr(H^{1/2}) = Σ √max(λᵢ, 0) — appears in Lemma 2 / Theorem 7 bounds.
    pub fn trace_sqrt(&self) -> f64 {
        self.values.iter().map(|&l| l.max(0.0).sqrt()).sum()
    }

    /// μ such that max |Q_ij| = μ/√n — the paper's Hessian incoherence
    /// parameter (Definition 1).
    pub fn incoherence_mu(&self) -> f64 {
        let n = self.vectors.rows as f64;
        self.vectors.max_abs() * n.sqrt()
    }

    /// Fraction of eigenvalues > `frac` · λ_max ("approximate fractional
    /// rank", Table 6).
    pub fn approx_frac_rank(&self, frac: f64) -> f64 {
        let lmax = self.values.last().copied().unwrap_or(0.0).max(0.0);
        if lmax == 0.0 {
            return 0.0;
        }
        let k = self.values.iter().filter(|&&l| l > frac * lmax).count();
        k as f64 / self.values.len() as f64
    }

    /// Fraction of numerically nonzero eigenvalues ("absolute fractional
    /// rank", Table 6).
    pub fn abs_frac_rank(&self) -> f64 {
        let lmax = self.values.last().copied().unwrap_or(0.0).max(1e-300);
        let k = self
            .values
            .iter()
            .filter(|&&l| l > 1e-10 * lmax)
            .count();
        k as f64 / self.values.len() as f64
    }

    pub fn reconstruct(&self) -> Mat {
        let qs = self.vectors.scale_cols(&self.values);
        qs.matmul_naive(&self.vectors.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::{random_spd, random_low_rank_psd};

    #[test]
    fn eigen_reconstructs() {
        let mut rng = Rng::new(40);
        for n in [2, 5, 20] {
            let h = random_spd(&mut rng, n, 1e-3);
            let e = eigen_sym(&h, 1e-13, 50);
            assert!(max_abs_diff(&e.reconstruct(), &h) < 1e-7, "n={n}");
        }
    }

    #[test]
    fn eigen_vectors_orthonormal() {
        let mut rng = Rng::new(41);
        let h = random_spd(&mut rng, 15, 1e-3);
        let e = eigen_sym(&h, 1e-13, 50);
        let qtq = e.vectors.transpose().matmul_naive(&e.vectors);
        assert!(max_abs_diff(&qtq, &Mat::eye(15)) < 1e-8);
    }

    #[test]
    fn eigen_of_diagonal() {
        let h = Mat::diag(&[3.0, 1.0, 2.0]);
        let e = eigen_sym(&h, 1e-14, 50);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn low_rank_detected() {
        let mut rng = Rng::new(42);
        let h = random_low_rank_psd(&mut rng, 24, 4);
        let e = eigen_sym(&h, 1e-13, 60);
        assert!(e.approx_frac_rank(0.01) <= 5.0 / 24.0 + 1e-12);
    }

    #[test]
    fn trace_sqrt_matches_eigs() {
        let h = Mat::diag(&[4.0, 9.0, 16.0]);
        let e = eigen_sym(&h, 1e-14, 50);
        assert!((e.trace_sqrt() - 9.0).abs() < 1e-9);
    }
}
