//! Randomized fast Walsh–Hadamard transform (RHT) — the QuIP# incoherence
//! backend: V = B · D · P with P a seeded random permutation, D a seeded
//! random ±1 diagonal, and B the orthonormal fast Walsh–Hadamard butterfly
//! applied blockwise.
//!
//! For n a power of two, B is the single n-point transform at O(n log n).
//! Other sizes decompose along the binary expansion of n — e.g.
//! 13 = 8 + 4 + 1 gives blocks H₈ ⊕ H₄ ⊕ H₁ — each block an independent
//! orthonormal FWHT, so B stays orthogonal. A single blocked round would
//! leave the trailing small blocks (down to H₁) barely mixed, so for
//! non-power-of-two sizes a **second** seeded round is composed on top:
//! V = B·D₂·P₂ · B·D₁·P₁. The second permutation scatters every block's
//! output across all blocks before the second butterfly, restoring global
//! mixing; power-of-two sizes keep the single cheap round.
//!
//! Compared to the Kronecker operator the RHT needs no stored factor
//! matrices at all (signs and permutations regenerate from the seed) and
//! its butterfly is pure add/sub — the per-token inference cost drops from
//! O(n(p+q)) multiplies to O(n log n) additions plus one scale.

use super::matrix::Mat;
use super::transform::{Transform, TransformKind};
use crate::util::rng::Rng;

/// In-place orthonormal FWHT on a power-of-two-length slice:
/// x ← H x / √len. H is symmetric and H² = len·I, so this same routine is
/// its own inverse. Generated for f64 (quantization) and f32 (inference).
macro_rules! fwht_impl {
    ($name:ident, $t:ty) => {
        fn $name(x: &mut [$t]) {
            let n = x.len();
            debug_assert!(n.is_power_of_two());
            if n == 1 {
                return;
            }
            let mut h = 1;
            while h < n {
                let mut i = 0;
                while i < n {
                    for j in i..i + h {
                        let (a, b) = (x[j], x[j + h]);
                        x[j] = a + b;
                        x[j + h] = a - b;
                    }
                    i += 2 * h;
                }
                h *= 2;
            }
            let scale = 1.0 / (n as $t).sqrt();
            for v in x.iter_mut() {
                *v *= scale;
            }
        }
    };
}

fwht_impl!(fwht_f64, f64);
fwht_impl!(fwht_f32, f32);

/// Power-of-two blocks covering 0..n, descending (binary expansion of n).
fn blocks_of(n: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut off = 0;
    let mut rem = n;
    while rem > 0 {
        let len = 1usize << (usize::BITS - 1 - rem.leading_zeros());
        out.push((off, len));
        off += len;
        rem -= len;
    }
    out
}

/// One seeded round of randomization: a ±1 diagonal and a permutation.
struct Round {
    /// Random ±1 diagonal, stored once as f32 (exact in both widths).
    sign: Vec<f32>,
    /// (P x)_i = x[perm[i]]. Identity in round 1 when the Table-5
    /// `permute` ablation is off; always random in round 2 (structural).
    perm: Vec<usize>,
}

impl Round {
    fn new(sign_rng: &mut Rng, perm_rng: &mut Rng, n: usize, permute: bool) -> Round {
        let sign = (0..n)
            .map(|_| if sign_rng.coin(0.5) { 1.0 } else { -1.0 })
            .collect();
        let perm = if permute {
            perm_rng.permutation(n)
        } else {
            (0..n).collect()
        };
        Round { sign, perm }
    }

    fn inv_perm(&self) -> Vec<usize> {
        let mut inv = vec![0usize; self.perm.len()];
        for (i, &pi) in self.perm.iter().enumerate() {
            inv[pi] = i;
        }
        inv
    }
}

/// A seeded randomized Hadamard operator on ℝⁿ.
pub struct RandomizedHadamard {
    n: usize,
    seed: u64,
    r1: Round,
    /// Second mixing round; present only for non-power-of-two n (see the
    /// module docs).
    r2: Option<Round>,
    /// (offset, len) of each power-of-two butterfly block.
    blocks: Vec<(usize, usize)>,
}

impl RandomizedHadamard {
    /// Deterministically construct from a seed; `permute` toggles the
    /// random permutations (the Table-5 ablation, matching
    /// [`super::kron::KronOrtho::from_seed_with`]).
    pub fn from_seed_with(seed: u64, n: usize, permute: bool) -> RandomizedHadamard {
        assert!(n > 0);
        let root = Rng::new(seed);
        let blocks = blocks_of(n);
        let r1 = Round::new(&mut root.fork(1), &mut root.fork(3), n, permute);
        // The second round's permutation is what scatters block outputs
        // across blocks — it is structural to the non-power-of-two
        // decomposition, not part of the Table-5 permutation heuristic,
        // so it stays on even when `permute` is ablated off.
        let r2 = if blocks.len() > 1 {
            Some(Round::new(&mut root.fork(2), &mut root.fork(4), n, true))
        } else {
            None
        };
        RandomizedHadamard {
            n,
            seed,
            r1,
            r2,
            blocks,
        }
    }

    /// All butterfly blocks in place on a vector.
    fn fwht_vec64(&self, z: &mut [f64]) {
        for &(off, len) in &self.blocks {
            fwht_f64(&mut z[off..off + len]);
        }
    }

    fn fwht_vec32(&self, z: &mut [f32]) {
        for &(off, len) in &self.blocks {
            fwht_f32(&mut z[off..off + len]);
        }
    }

    /// All butterfly blocks across the rows of a matrix (columns ride
    /// along elementwise) — the one shared implementation both matrix
    /// directions use.
    fn fwht_rows(&self, z: &mut Mat) {
        let c = z.cols;
        for &(off, len) in &self.blocks {
            let mut h = 1;
            while h < len {
                let mut i = off;
                while i < off + len {
                    for j in i..i + h {
                        for k in 0..c {
                            let a = z[(j, k)];
                            let b = z[(j + h, k)];
                            z[(j, k)] = a + b;
                            z[(j + h, k)] = a - b;
                        }
                    }
                    i += 2 * h;
                }
                h *= 2;
            }
            let scale = 1.0 / (len as f64).sqrt();
            for i in off..off + len {
                for v in z.row_mut(i) {
                    *v *= scale;
                }
            }
        }
    }

    /// One forward round on a matrix: B · D · P applied to the rows.
    fn round_mat_fwd(&self, m: &Mat, r: &Round) -> Mat {
        let mut z = m.permute_rows(&r.perm);
        for i in 0..self.n {
            let s = r.sign[i] as f64;
            for v in z.row_mut(i) {
                *v *= s;
            }
        }
        self.fwht_rows(&mut z);
        z
    }

    /// One inverse round on a matrix: Pᵀ · D · B applied to the rows.
    fn round_mat_inv(&self, m: &Mat, r: &Round) -> Mat {
        let mut t = m.clone();
        self.fwht_rows(&mut t);
        for i in 0..self.n {
            let s = r.sign[i] as f64;
            for v in t.row_mut(i) {
                *v *= s;
            }
        }
        t.permute_rows(&r.inv_perm())
    }
}

impl Transform for RandomizedHadamard {
    fn kind(&self) -> TransformKind {
        TransformKind::Hadamard
    }

    fn n(&self) -> usize {
        self.n
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn forward_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut z = vec![0.0; self.n];
        for i in 0..self.n {
            z[i] = x[self.r1.perm[i]] * self.r1.sign[i] as f64;
        }
        self.fwht_vec64(&mut z);
        if let Some(r2) = &self.r2 {
            let mut t = vec![0.0; self.n];
            for i in 0..self.n {
                t[i] = z[r2.perm[i]] * r2.sign[i] as f64;
            }
            self.fwht_vec64(&mut t);
            return t;
        }
        z
    }

    fn inverse_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.n);
        // Each round's inverse is Pᵀ D B (B and D are symmetric); undo
        // round 2 first, then round 1.
        let mut t = y.to_vec();
        if let Some(r2) = &self.r2 {
            self.fwht_vec64(&mut t);
            let mut u = vec![0.0; self.n];
            for i in 0..self.n {
                u[r2.perm[i]] = t[i] * r2.sign[i] as f64;
            }
            t = u;
        }
        self.fwht_vec64(&mut t);
        let mut x = vec![0.0; self.n];
        for i in 0..self.n {
            x[self.r1.perm[i]] = t[i] * self.r1.sign[i] as f64;
        }
        x
    }

    fn forward_mat_left(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.n);
        let mut z = self.round_mat_fwd(m, &self.r1);
        if let Some(r2) = &self.r2 {
            z = self.round_mat_fwd(&z, r2);
        }
        z
    }

    fn inverse_mat_left(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.n);
        let mut t = m.clone();
        if let Some(r2) = &self.r2 {
            t = self.round_mat_inv(&t, r2);
        }
        self.round_mat_inv(&t, &self.r1)
    }

    fn forward_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            y[i] = x[self.r1.perm[i]] * self.r1.sign[i];
        }
        self.fwht_vec32(y);
        if let Some(r2) = &self.r2 {
            let t = &mut scratch[..self.n];
            for i in 0..self.n {
                t[i] = y[r2.perm[i]] * r2.sign[i];
            }
            self.fwht_vec32(t);
            y.copy_from_slice(t);
        }
    }

    fn inverse_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        let t = &mut scratch[..self.n];
        t.copy_from_slice(x);
        if let Some(r2) = &self.r2 {
            // Undo round 2: scatter B x through P₂ᵀ D₂ into y, then pull
            // back into the scratch for the round-1 inverse.
            self.fwht_vec32(t);
            for i in 0..self.n {
                y[r2.perm[i]] = t[i] * r2.sign[i];
            }
            t.copy_from_slice(y);
        }
        self.fwht_vec32(t);
        for i in 0..self.n {
            y[self.r1.perm[i]] = t[i] * self.r1.sign[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::testkit::{propcheck, random_mat, random_spd};

    #[test]
    fn blocks_cover_binary_expansion() {
        assert_eq!(blocks_of(16), vec![(0, 16)]);
        assert_eq!(blocks_of(13), vec![(0, 8), (8, 4), (12, 1)]);
        assert_eq!(blocks_of(1), vec![(0, 1)]);
        assert_eq!(blocks_of(24), vec![(0, 16), (16, 8)]);
        for n in 1..=64 {
            let b = blocks_of(n);
            assert_eq!(b.iter().map(|&(_, l)| l).sum::<usize>(), n);
            assert!(b.iter().all(|&(_, l)| l.is_power_of_two()));
        }
    }

    #[test]
    fn second_round_only_for_non_powers_of_two() {
        assert!(RandomizedHadamard::from_seed_with(1, 64, true).r2.is_none());
        assert!(RandomizedHadamard::from_seed_with(1, 1, true).r2.is_none());
        assert!(RandomizedHadamard::from_seed_with(1, 13, true).r2.is_some());
        assert!(RandomizedHadamard::from_seed_with(1, 24, true).r2.is_some());
    }

    #[test]
    fn fwht_matches_dense_hadamard() {
        // H₄ explicitly: Sylvester rows dotted with x, over √4.
        let mut x = [1.0f64, 2.0, 3.0, 4.0];
        fwht_f64(&mut x);
        let want = [5.0, -1.0, -2.0, 0.0];
        for (a, b) in x.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn fwht_is_involutive() {
        propcheck("fwht-involution", 10, |rng| {
            let k = 1usize << rng.below(7);
            let x: Vec<f64> = (0..k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut y = x.clone();
            fwht_f64(&mut y);
            fwht_f64(&mut y);
            for (a, b) in y.iter().zip(&x) {
                assert!((a - b).abs() < 1e-10, "len={k}");
            }
        });
    }

    #[test]
    fn dense_is_orthogonal_including_non_powers_of_two() {
        for n in [2usize, 7, 8, 12, 13, 24, 57] {
            let t = RandomizedHadamard::from_seed_with(123, n, true);
            let v = t.dense();
            let vtv = v.transpose().matmul_naive(&v);
            assert!(max_abs_diff(&vtv, &Mat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn inverse_inverts_forward() {
        propcheck("rht-involution", 10, |rng| {
            let n = 1 + rng.below(40);
            let t = RandomizedHadamard::from_seed_with(7, n, true);
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let back = t.inverse_vec(&t.forward_vec(&x));
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-10, "n={n}");
            }
        });
    }

    #[test]
    fn mat_left_matches_dense() {
        for n in [13usize, 16] {
            // 13 exercises the two-round block path, 16 the single round.
            let t = RandomizedHadamard::from_seed_with(9, n, true);
            let m = random_mat(&mut crate::util::rng::Rng::new(2), n, 5);
            let fast = t.forward_mat_left(&m);
            let dense = t.dense().matmul_naive(&m);
            assert!(max_abs_diff(&fast, &dense) < 1e-9, "n={n}");
            let fast_t = t.inverse_mat_left(&m);
            let dense_t = t.dense().transpose().matmul_naive(&m);
            assert!(max_abs_diff(&fast_t, &dense_t) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn conj_preserves_trace_and_inverts() {
        let mut rng = crate::util::rng::Rng::new(77);
        for n in [16usize, 13] {
            let h = random_spd(&mut rng, n, 1e-3);
            let t = RandomizedHadamard::from_seed_with(3, n, true);
            let hc = t.conj_sym(&h);
            assert!((hc.trace() - h.trace()).abs() < 1e-8, "n={n}");
            let back = t.conj_sym_t(&hc);
            assert!(max_abs_diff(&back, &h) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn f32_matches_f64_and_inverts() {
        for n in [24usize, 13, 64] {
            let t = RandomizedHadamard::from_seed_with(9, n, true);
            let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).cos()).collect();
            let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let want = t.forward_vec(&x64);
            let mut got = vec![0.0f32; n];
            let mut scratch = vec![0.0f32; n];
            t.forward_f32(&x, &mut got, &mut scratch);
            for (a, b) in got.iter().zip(&want) {
                assert!((*a as f64 - b).abs() < 1e-5, "n={n}");
            }
            let mut back = vec![0.0f32; n];
            t.inverse_f32(&got.clone(), &mut back, &mut scratch);
            for (a, b) in back.iter().zip(&x) {
                assert!((a - b).abs() < 1e-5, "n={n}");
            }
        }
    }

    #[test]
    fn seeded_reproducible_and_permutation_toggles() {
        let a = RandomizedHadamard::from_seed_with(42, 24, true);
        let b = RandomizedHadamard::from_seed_with(42, 24, true);
        assert_eq!(a.r1.perm, b.r1.perm);
        assert_eq!(a.r1.sign, b.r1.sign);
        let c = RandomizedHadamard::from_seed_with(42, 24, false);
        assert_eq!(c.r1.perm, (0..24).collect::<Vec<_>>());
        // The second round's block-scattering permutation is structural
        // and survives the permute ablation.
        assert_ne!(c.r2.as_ref().unwrap().perm, (0..24).collect::<Vec<_>>());
        let d = RandomizedHadamard::from_seed_with(43, 24, true);
        assert_ne!(a.r1.sign, d.r1.sign);
    }

    #[test]
    fn spreads_outliers_at_power_of_two() {
        // The incoherence property: a spike e_j maps to a vector whose
        // entries all have magnitude exactly 1/√n when n is one block.
        let n = 64;
        let t = RandomizedHadamard::from_seed_with(5, n, true);
        let mut x = vec![0.0; n];
        x[17] = 1.0;
        let y = t.forward_vec(&x);
        let maxabs = y.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!((maxabs - 1.0 / 8.0).abs() < 1e-12, "max {maxabs}");
    }

    #[test]
    fn spreads_outliers_at_non_power_of_two() {
        // With a single blocked round, sizes with a trailing H₁ block
        // (13, 57) leave exactly one basis vector per seed completely
        // unmixed (|Ve_j| has a 1.0 entry). The second round scatters
        // those; an unmixed column survives only when the spike lands in
        // H₁ in *both* rounds (probability ~1/n per seed). Over three
        // seeds the single-round construction would score exactly one
        // near-1 column each; the two-round one almost never does.
        for n in [13usize, 24, 57] {
            let mut near_one = 0usize;
            for seed in [5u64, 6, 7] {
                let t = RandomizedHadamard::from_seed_with(seed, n, true);
                let mut x = vec![0.0; n];
                for j in 0..n {
                    x[j] = 1.0;
                    let y = t.forward_vec(&x);
                    let maxabs = y.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                    if maxabs > 0.99 {
                        near_one += 1;
                    }
                    x[j] = 0.0;
                }
            }
            assert!(near_one <= 2, "n={n}: {near_one} unmixed basis vectors over 3 seeds");
        }
    }
}
