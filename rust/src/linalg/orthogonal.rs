//! Haar-random orthogonal matrices via Householder QR of a Gaussian matrix
//! (with the R-diagonal sign correction that makes the distribution exactly
//! Haar). These are the Kronecker factors of QuIP's incoherence processing.

use super::matrix::Mat;
use crate::util::rng::Rng;

/// QR via Householder reflections. Returns (Q, R) with Q orthogonal
/// (m×m) and R upper triangular (m×n), A = Q R.
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    let mut r = a.clone();
    let mut q = Mat::eye(m);
    let mut v = vec![0.0; m];
    for k in 0..n.min(m.saturating_sub(1)) {
        // Householder vector for column k below the diagonal.
        let mut normx = 0.0;
        for i in k..m {
            normx += r[(i, k)] * r[(i, k)];
        }
        let normx = normx.sqrt();
        if normx < 1e-300 {
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -normx } else { normx };
        let mut vnorm2 = 0.0;
        for i in k..m {
            v[i] = r[(i, k)];
            if i == k {
                v[i] -= alpha;
            }
            vnorm2 += v[i] * v[i];
        }
        if vnorm2 < 1e-300 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        // R ← (I − β v vᵀ) R
        for j in k..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i] * r[(i, j)];
            }
            let s = beta * s;
            for i in k..m {
                r[(i, j)] -= s * v[i];
            }
        }
        // Q ← Q (I − β v vᵀ)
        for i in 0..m {
            let mut s = 0.0;
            for j in k..m {
                s += q[(i, j)] * v[j];
            }
            let s = beta * s;
            for j in k..m {
                q[(i, j)] -= s * v[j];
            }
        }
    }
    // Zero numerical noise below the diagonal of R.
    for i in 0..m {
        for j in 0..n.min(i) {
            r[(i, j)] = 0.0;
        }
    }
    (q, r)
}

/// Sample an n×n orthogonal matrix from the Haar measure:
/// QR of a standard Gaussian matrix, then Q · sign(diag(R)).
pub fn haar_orthogonal(rng: &mut Rng, n: usize) -> Mat {
    let g = Mat::from_fn(n, n, |_, _| rng.normal());
    let (mut q, r) = qr(&g);
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

/// Factor n ≈ p·q with p, q as close to √n as possible (the paper's
/// two-factor Kronecker split). Returns (p, q) with p ≤ q, p·q = n.
pub fn balanced_factor(n: usize) -> (usize, usize) {
    assert!(n > 0);
    let mut best = (1, n);
    let mut p = (n as f64).sqrt() as usize + 1;
    while p >= 1 {
        if n % p == 0 {
            let q = n / p;
            let (lo, hi) = if p <= q { (p, q) } else { (q, p) };
            if hi - lo < best.1 - best.0 {
                best = (lo, hi);
            }
            if lo * lo <= n {
                // first hit below sqrt is the most balanced
                return best;
            }
        }
        p -= 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(50);
        for &(m, n) in &[(4, 4), (6, 3), (9, 9)] {
            let a = Mat::from_fn(m, n, |_, _| rng.normal());
            let (q, r) = qr(&a);
            assert!(max_abs_diff(&q.matmul_naive(&r), &a) < 1e-9);
            let qtq = q.transpose().matmul_naive(&q);
            assert!(max_abs_diff(&qtq, &Mat::eye(m)) < 1e-9);
            // R upper triangular
            for i in 0..m {
                for j in 0..n.min(i) {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn haar_is_orthogonal() {
        let mut rng = Rng::new(51);
        for n in [1, 2, 8, 16] {
            let q = haar_orthogonal(&mut rng, n);
            let qtq = q.transpose().matmul_naive(&q);
            assert!(max_abs_diff(&qtq, &Mat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn haar_entries_concentrate() {
        // Entries of a Haar orthogonal have E[q_ij²] = 1/n; max entry of a
        // 64×64 sample should be far below 1 (incoherence in action).
        let mut rng = Rng::new(52);
        let n = 64;
        let q = haar_orthogonal(&mut rng, n);
        let mean_sq: f64 = q.data.iter().map(|x| x * x).sum::<f64>() / (n * n) as f64;
        assert!((mean_sq - 1.0 / n as f64).abs() < 1e-3);
        assert!(q.max_abs() < 0.7);
    }

    #[test]
    fn haar_seeded_reproducible() {
        let a = haar_orthogonal(&mut Rng::new(99), 8);
        let b = haar_orthogonal(&mut Rng::new(99), 8);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn balanced_factor_cases() {
        assert_eq!(balanced_factor(64), (8, 8));
        assert_eq!(balanced_factor(12), (3, 4));
        assert_eq!(balanced_factor(7), (1, 7)); // prime: degenerate split
        assert_eq!(balanced_factor(768), (24, 32));
        let (p, q) = balanced_factor(1024);
        assert_eq!(p * q, 1024);
        assert_eq!((p, q), (32, 32));
    }
}
