//! Row-major dense `f64` matrix.

use std::fmt;

/// Dense row-major matrix of f64 (quantization math runs in f64 for the
/// same reason the reference implementation runs layer math in fp64:
/// LDL feedback amplifies rounding error over n columns).
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f64>(rows: usize, cols: usize, mut f: F) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    pub fn diag(d: &[f64]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    pub fn trace(&self) -> f64 {
        self.diagonal().iter().sum()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    /// Scale column j by s[j] (right-multiplication by diag(s)).
    pub fn scale_cols(&self, s: &[f64]) -> Mat {
        assert_eq!(s.len(), self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            let row = out.row_mut(i);
            for (x, &f) in row.iter_mut().zip(s) {
                *x *= f;
            }
        }
        out
    }

    /// Scale row i by s[i] (left-multiplication by diag(s)).
    pub fn scale_rows(&self, s: &[f64]) -> Mat {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let f = s[i];
            for x in out.row_mut(i) {
                *x *= f;
            }
        }
        out
    }

    /// Permute columns: out[:, j] = self[:, perm[j]].
    pub fn permute_cols(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Permute rows: out[i, :] = self[perm[i], :].
    pub fn permute_rows(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.rows);
        let mut out = Mat::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        out
    }

    /// Symmetric permutation: out = P self Pᵀ with out[i,j] = self[perm[i], perm[j]].
    pub fn permute_sym(&self, perm: &[usize]) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(i, j)] = self[(perm[i], perm[j])];
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Force exact symmetry: (A + Aᵀ)/2.
    pub fn symmetrize(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                out[(i, j)] = v;
                out[(j, i)] = v;
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// Naive matmul — reference implementation; use `gemm::matmul` on hot
    /// paths.
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let row = out.row_mut(i);
                for j in 0..other.cols {
                    row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Blocked threaded matmul (delegates to `gemm`).
    pub fn matmul(&self, other: &Mat) -> Mat {
        super::gemm::matmul(self, other)
    }

    /// Extract a contiguous sub-matrix (row0..row1, col0..col1).
    pub fn slice(&self, row0: usize, row1: usize, col0: usize, col1: usize) -> Mat {
        assert!(row1 <= self.rows && col1 <= self.cols && row0 <= row1 && col0 <= col1);
        let mut out = Mat::zeros(row1 - row0, col1 - col0);
        for i in row0..row1 {
            out.row_mut(i - row0)
                .copy_from_slice(&self.row(i)[col0..col1]);
        }
        out
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolled; autovectorizes well.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

/// Max elementwise |a-b|.
pub fn max_abs_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data
        .iter()
        .zip(&b.data)
        .fold(0.0, |m, (x, y)| m.max((x - y).abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_transpose() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(1, 2)], 12.0);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 12.0);
    }

    #[test]
    fn matmul_naive_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul_naive(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let m = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        let e = Mat::eye(4);
        assert_eq!(m.matmul_naive(&e).data, m.data);
        assert_eq!(e.matmul_naive(&m).data, m.data);
    }

    #[test]
    fn permute_sym_matches_manual() {
        let m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let perm = vec![2, 0, 1];
        let p = m.permute_sym(&perm);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(p[(i, j)], m[(perm[i], perm[j])]);
            }
        }
    }

    #[test]
    fn permute_cols_then_inverse_is_identity() {
        let m = Mat::from_fn(2, 5, |i, j| (i * 5 + j) as f64);
        let perm = vec![3, 0, 4, 1, 2];
        let mut inv = vec![0usize; 5];
        for (j, &p) in perm.iter().enumerate() {
            inv[p] = j;
        }
        let back = m.permute_cols(&perm).permute_cols(&inv);
        assert_eq!(back.data, m.data);
    }

    #[test]
    fn scale_rows_cols() {
        let m = Mat::from_fn(2, 2, |_, _| 1.0);
        let r = m.scale_rows(&[2.0, 3.0]);
        assert_eq!(r.data, vec![2.0, 2.0, 3.0, 3.0]);
        let c = m.scale_cols(&[2.0, 3.0]);
        assert_eq!(c.data, vec![2.0, 3.0, 2.0, 3.0]);
    }

    #[test]
    fn dot_matches_reference() {
        let a: Vec<f64> = (0..131).map(|i| i as f64 * 0.25).collect();
        let b: Vec<f64> = (0..131).map(|i| (i as f64).sin()).collect();
        let reference: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - reference).abs() < 1e-9);
    }

    #[test]
    fn slice_extracts_block() {
        let m = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = m.slice(1, 3, 2, 4);
        assert_eq!(s.data, vec![6.0, 7.0, 10.0, 11.0]);
    }

    #[test]
    fn matvec_known() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
    }
}
