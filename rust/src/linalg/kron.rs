//! Fast structured orthogonal operators: V = (L ⊗ R) · P with L, R small
//! Haar-orthogonal factors and P a random permutation (QuIP §4.1–4.2).
//!
//! Multiplying x ∈ ℝⁿ by V costs O(n(p+q)) = o(n²): permute, reshape to
//! p×q, left/right small matmuls, reshape back. The permutation is the
//! paper's "randomly permute entries at the fast matrix multiplication
//! step" heuristic (Table 5 ablates it).

use super::matrix::Mat;
use super::orthogonal::{balanced_factor, haar_orthogonal};
use super::transform::{Transform, TransformKind};
use crate::linalg::gemm::sdot as sdot32;
use crate::util::rng::Rng;

/// A seeded fast orthogonal operator on ℝⁿ.
#[derive(Clone, Debug)]
pub struct KronOrtho {
    pub n: usize,
    pub p: usize,
    pub q: usize,
    /// p×p Haar-orthogonal left factor.
    pub left: Mat,
    /// q×q Haar-orthogonal right factor.
    pub right: Mat,
    /// Permutation applied before the Kronecker multiply:
    /// (P x)_i = x[perm[i]].
    pub perm: Vec<usize>,
    /// Inverse permutation (cached).
    inv_perm: Vec<usize>,
}

impl KronOrtho {
    /// Deterministically construct from a seed. The same seed always
    /// regenerates the same operator — this is what makes storing only the
    /// seed in quantized artifacts possible.
    pub fn from_seed(seed: u64, n: usize) -> KronOrtho {
        Self::from_seed_with(seed, n, true)
    }

    /// As `from_seed`, with the random permutation optionally disabled
    /// (identity) — used by the Table 5 ablation.
    pub fn from_seed_with(seed: u64, n: usize, permute: bool) -> KronOrtho {
        let (p, q) = balanced_factor(n);
        let root = Rng::new(seed);
        let left = haar_orthogonal(&mut root.fork(1), p);
        let right = haar_orthogonal(&mut root.fork(2), q);
        let perm = if permute {
            root.fork(3).permutation(n)
        } else {
            (0..n).collect()
        };
        let mut inv_perm = vec![0usize; n];
        for (i, &pi) in perm.iter().enumerate() {
            inv_perm[pi] = i;
        }
        KronOrtho {
            n,
            p,
            q,
            left,
            right,
            perm,
            inv_perm,
        }
    }

    /// y = V x.
    pub fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let (p, q) = (self.p, self.q);
        // z = P x
        let mut z = vec![0.0; self.n];
        for i in 0..self.n {
            z[i] = x[self.perm[i]];
        }
        // Z: p×q row-major; Y = L Z Rᵀ
        let mut tmp = vec![0.0; self.n]; // L Z : p×q
        for a in 0..p {
            let lrow = self.left.row(a);
            let trow = &mut tmp[a * q..(a + 1) * q];
            for (aa, &lv) in lrow.iter().enumerate() {
                if lv == 0.0 {
                    continue;
                }
                let zrow = &z[aa * q..(aa + 1) * q];
                for b in 0..q {
                    trow[b] += lv * zrow[b];
                }
            }
        }
        let mut y = vec![0.0; self.n]; // (L Z) Rᵀ : p×q
        for a in 0..p {
            let trow = &tmp[a * q..(a + 1) * q];
            let yrow = &mut y[a * q..(a + 1) * q];
            for b in 0..q {
                yrow[b] = super::matrix::dot(trow, self.right.row(b));
            }
        }
        y
    }

    /// x = Vᵀ y.
    pub fn apply_t_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.n);
        let (p, q) = (self.p, self.q);
        // Z = Lᵀ Y R  (Y p×q row-major)
        let mut tmp = vec![0.0; self.n]; // Lᵀ Y : p×q
        for a in 0..p {
            // row a of Lᵀ is column a of L
            let trow = &mut tmp[a * q..(a + 1) * q];
            for aa in 0..p {
                let lv = self.left[(aa, a)];
                if lv == 0.0 {
                    continue;
                }
                let yrow = &y[aa * q..(aa + 1) * q];
                for b in 0..q {
                    trow[b] += lv * yrow[b];
                }
            }
        }
        let mut z = vec![0.0; self.n]; // (Lᵀ Y) R : p×q
        for a in 0..p {
            let trow = &tmp[a * q..(a + 1) * q];
            let zrow = &mut z[a * q..(a + 1) * q];
            for (bb, &tv) in trow.iter().enumerate() {
                if tv == 0.0 {
                    continue;
                }
                let rrow = self.right.row(bb);
                for b in 0..q {
                    zrow[b] += tv * rrow[b];
                }
            }
        }
        // x = Pᵀ z : x[perm[i]] = z[i]
        let mut x = vec![0.0; self.n];
        for i in 0..self.n {
            x[self.perm[i]] = z[i];
        }
        x
    }

    /// V M (M is n×c; applies V to every column).
    pub fn apply_mat_left(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.n);
        let c = m.cols;
        // Permute rows, then batched Kronecker apply via two matmul passes.
        let pm = m.permute_rows(&self.perm);
        let (p, q) = (self.p, self.q);
        // View pm as (p, q*c)? No: row-major (n×c) = (p·q)×c; axis-0 apply:
        // tmp[(a', b), :] = Σ_a L[a',a] pm[(a,b), :]
        let mut tmp = Mat::zeros(self.n, c);
        for ap in 0..p {
            for a in 0..p {
                let lv = self.left[(ap, a)];
                if lv == 0.0 {
                    continue;
                }
                for b in 0..q {
                    let src = pm.row(a * q + b).to_vec();
                    let dst = tmp.row_mut(ap * q + b);
                    for (d, s) in dst.iter_mut().zip(&src) {
                        *d += lv * s;
                    }
                }
            }
        }
        // axis-1 apply: out[(a, b'), :] = Σ_b R[b',b] tmp[(a,b), :]
        let mut out = Mat::zeros(self.n, c);
        for a in 0..p {
            for bp in 0..q {
                for b in 0..q {
                    let rv = self.right[(bp, b)];
                    if rv == 0.0 {
                        continue;
                    }
                    let src = tmp.row(a * q + b).to_vec();
                    let dst = out.row_mut(a * q + bp);
                    for (d, s) in dst.iter_mut().zip(&src) {
                        *d += rv * s;
                    }
                }
            }
        }
        out
    }

    /// Vᵀ M.
    pub fn apply_t_mat_left(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.n);
        let c = m.cols;
        let (p, q) = (self.p, self.q);
        let mut tmp = Mat::zeros(self.n, c);
        for ap in 0..p {
            for a in 0..p {
                let lv = self.left[(a, ap)]; // Lᵀ
                if lv == 0.0 {
                    continue;
                }
                for b in 0..q {
                    let src = m.row(a * q + b).to_vec();
                    let dst = tmp.row_mut(ap * q + b);
                    for (d, s) in dst.iter_mut().zip(&src) {
                        *d += lv * s;
                    }
                }
            }
        }
        let mut z = Mat::zeros(self.n, c);
        for a in 0..p {
            for bp in 0..q {
                for b in 0..q {
                    let rv = self.right[(b, bp)]; // Rᵀ
                    if rv == 0.0 {
                        continue;
                    }
                    let src = tmp.row(a * q + b).to_vec();
                    let dst = z.row_mut(a * q + bp);
                    for (d, s) in dst.iter_mut().zip(&src) {
                        *d += rv * s;
                    }
                }
            }
        }
        z.permute_rows(&self.inv_perm)
    }

    /// M Vᵀ (M is c×n).
    pub fn apply_mat_right_t(&self, m: &Mat) -> Mat {
        self.apply_mat_left(&m.transpose()).transpose()
    }

    /// M V (M is c×n).
    pub fn apply_mat_right(&self, m: &Mat) -> Mat {
        self.apply_t_mat_left(&m.transpose()).transpose()
    }

    /// V H Vᵀ (conjugation; H n×n).
    pub fn conj_sym(&self, h: &Mat) -> Mat {
        let vh = self.apply_mat_left(h);
        self.apply_mat_left(&vh.transpose()).transpose()
    }

    /// Vᵀ H V.
    pub fn conj_sym_t(&self, h: &Mat) -> Mat {
        let vth = self.apply_t_mat_left(h);
        self.apply_t_mat_left(&vth.transpose()).transpose()
    }

    /// Materialize V as a dense n×n matrix (tests / diagnostics only).
    pub fn dense(&self) -> Mat {
        let mut v = Mat::zeros(self.n, self.n);
        let mut e = vec![0.0; self.n];
        for j in 0..self.n {
            e[j] = 1.0;
            let col = self.apply_vec(&e);
            v.set_col(j, &col);
            e[j] = 0.0;
        }
        v
    }
}

/// The Kronecker backend of the incoherence-transform subsystem: a
/// [`KronOrtho`] plus f32 copies of its factors for the allocation-free
/// inference applies required by the [`Transform`] contract.
pub struct KronTransform {
    k: KronOrtho,
    seed: u64,
    left32: Vec<f32>,
    right32: Vec<f32>,
}

impl KronTransform {
    pub fn from_seed_with(seed: u64, n: usize, permute: bool) -> KronTransform {
        let k = KronOrtho::from_seed_with(seed, n, permute);
        let left32 = k.left.data.iter().map(|&x| x as f32).collect();
        let right32 = k.right.data.iter().map(|&x| x as f32).collect();
        KronTransform {
            k,
            seed,
            left32,
            right32,
        }
    }
}

impl Transform for KronTransform {
    fn kind(&self) -> TransformKind {
        TransformKind::Kron
    }

    fn n(&self) -> usize {
        self.k.n
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn forward_vec(&self, x: &[f64]) -> Vec<f64> {
        self.k.apply_vec(x)
    }

    fn inverse_vec(&self, y: &[f64]) -> Vec<f64> {
        self.k.apply_t_vec(y)
    }

    fn forward_mat_left(&self, m: &Mat) -> Mat {
        self.k.apply_mat_left(m)
    }

    fn inverse_mat_left(&self, m: &Mat) -> Mat {
        self.k.apply_t_mat_left(m)
    }

    /// y = V x (f32 twin of [`KronOrtho::apply_vec`]); `scratch` holds the
    /// intermediate L Z product.
    fn forward_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]) {
        let (p, q) = (self.k.p, self.k.q);
        let n = p * q;
        debug_assert_eq!(x.len(), n);
        // z = P x (into y as temp)
        for i in 0..n {
            y[i] = x[self.k.perm[i]];
        }
        // scratch = L Z
        scratch[..n].fill(0.0);
        for a in 0..p {
            let lrow = &self.left32[a * p..(a + 1) * p];
            let srow = &mut scratch[a * q..(a + 1) * q];
            for (aa, &lv) in lrow.iter().enumerate() {
                if lv == 0.0 {
                    continue;
                }
                let zrow = &y[aa * q..(aa + 1) * q];
                for b in 0..q {
                    srow[b] += lv * zrow[b];
                }
            }
        }
        // y = (L Z) Rᵀ
        for a in 0..p {
            let srow = &scratch[a * q..(a + 1) * q];
            let yrow = &mut y[a * q..(a + 1) * q];
            for b in 0..q {
                yrow[b] = sdot32(srow, &self.right32[b * q..(b + 1) * q]);
            }
        }
    }

    /// y = Vᵀ x.
    fn inverse_f32(&self, x: &[f32], y: &mut [f32], scratch: &mut [f32]) {
        let (p, q) = (self.k.p, self.k.q);
        let n = p * q;
        debug_assert_eq!(x.len(), n);
        // scratch = Lᵀ X
        scratch[..n].fill(0.0);
        for a in 0..p {
            let srow_range = a * q..(a + 1) * q;
            for aa in 0..p {
                let lv = self.left32[aa * p + a];
                if lv == 0.0 {
                    continue;
                }
                let xrow = &x[aa * q..(aa + 1) * q];
                let srow = &mut scratch[srow_range.clone()];
                for b in 0..q {
                    srow[b] += lv * xrow[b];
                }
            }
        }
        // y = Pᵀ ((Lᵀ X) R): the contract guarantees only n floats of
        // scratch (all holding Lᵀ X), so accumulate each output element
        // directly and scatter through the permutation.
        for a in 0..p {
            let srow = &scratch[a * q..(a + 1) * q];
            for b in 0..q {
                let mut acc = 0.0f32;
                for (bb, &sv) in srow.iter().enumerate() {
                    acc += sv * self.right32[bb * q + b];
                }
                y[self.k.perm[a * q + b]] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::testkit::random_spd;

    #[test]
    fn kron_transform_f32_matches_f64_and_inverts() {
        let n = 24;
        let t = KronTransform::from_seed_with(9, n, true);
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).cos()).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want = t.forward_vec(&x64);
        let mut got = vec![0.0f32; n];
        let mut scratch = vec![0.0f32; n];
        t.forward_f32(&x, &mut got, &mut scratch);
        for (a, b) in got.iter().zip(&want) {
            assert!((*a as f64 - b).abs() < 1e-5);
        }
        let mut back = vec![0.0f32; n];
        t.inverse_f32(&got.clone(), &mut back, &mut scratch);
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn dense_is_orthogonal() {
        for n in [6, 12, 16, 7] {
            let v = KronOrtho::from_seed(123, n).dense();
            let vtv = v.transpose().matmul_naive(&v);
            assert!(max_abs_diff(&vtv, &Mat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn apply_t_inverts_apply() {
        let k = KronOrtho::from_seed(7, 20);
        let x: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let y = k.apply_vec(&x);
        let back = k.apply_t_vec(&y);
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn mat_left_matches_dense() {
        let k = KronOrtho::from_seed(9, 12);
        let m = Mat::from_fn(12, 5, |i, j| (i * 5 + j) as f64 * 0.1);
        let fast = k.apply_mat_left(&m);
        let dense = k.dense().matmul_naive(&m);
        assert!(max_abs_diff(&fast, &dense) < 1e-9);
        let fast_t = k.apply_t_mat_left(&m);
        let dense_t = k.dense().transpose().matmul_naive(&m);
        assert!(max_abs_diff(&fast_t, &dense_t) < 1e-9);
    }

    #[test]
    fn mat_right_matches_dense() {
        let k = KronOrtho::from_seed(10, 12);
        let m = Mat::from_fn(4, 12, |i, j| ((i + j) as f64).cos());
        let fast = k.apply_mat_right_t(&m);
        let dense = m.matmul_naive(&k.dense().transpose());
        assert!(max_abs_diff(&fast, &dense) < 1e-9);
        let fast2 = k.apply_mat_right(&m);
        let dense2 = m.matmul_naive(&k.dense());
        assert!(max_abs_diff(&fast2, &dense2) < 1e-9);
    }

    #[test]
    fn conj_preserves_trace_and_spectrum_shape() {
        let mut rng = crate::util::rng::Rng::new(77);
        let h = random_spd(&mut rng, 16, 1e-3);
        let k = KronOrtho::from_seed(3, 16);
        let hc = k.conj_sym(&h);
        assert!((hc.trace() - h.trace()).abs() < 1e-8);
        // conj then conj_t returns the original
        let back = k.conj_sym_t(&hc);
        assert!(max_abs_diff(&back, &h) < 1e-8);
    }

    #[test]
    fn seeded_reproducible_and_permutation_toggles() {
        let a = KronOrtho::from_seed(42, 24);
        let b = KronOrtho::from_seed(42, 24);
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.left.data, b.left.data);
        let c = KronOrtho::from_seed_with(42, 24, false);
        assert_eq!(c.perm, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn prime_n_degenerates_gracefully() {
        let k = KronOrtho::from_seed(5, 13);
        assert_eq!(k.p * k.q, 13);
        let v = k.dense();
        let vtv = v.transpose().matmul_naive(&v);
        assert!(max_abs_diff(&vtv, &Mat::eye(13)) < 1e-9);
    }
}
