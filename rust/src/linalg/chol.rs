//! Cholesky decomposition (H = L Lᵀ) and SPD solves. Used by the OPTQ
//! reference implementation (which Cholesky-decomposes H⁻¹) and by tests.

use super::matrix::Mat;

/// Cholesky H = L Lᵀ, L lower triangular. Errors on non-PD input.
pub fn cholesky(h: &Mat) -> crate::Result<Mat> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = h[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    anyhow::bail!("matrix not positive definite at pivot {i} (s={s})");
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve H x = b for SPD H via Cholesky.
pub fn spd_solve(h: &Mat, b: &[f64]) -> crate::Result<Vec<f64>> {
    let l = cholesky(h)?;
    let y = super::solve::forward_sub(&l, b, false);
    Ok(super::solve::backward_sub_t(&l, &y, false))
}

/// Inverse of an SPD matrix via Cholesky (solves against each basis vector).
pub fn spd_inverse(h: &Mat) -> crate::Result<Mat> {
    let n = h.rows;
    let l = cholesky(h)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = super::solve::forward_sub(&l, &e, false);
        let x = super::solve::backward_sub_t(&l, &y, false);
        inv.set_col(j, &x);
        e[j] = 0.0;
    }
    Ok(inv.symmetrize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::random_spd;

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(20);
        for n in [1, 4, 17] {
            let h = random_spd(&mut rng, n, 1e-2);
            let l = cholesky(&h).unwrap();
            let back = l.matmul_naive(&l.transpose());
            assert!(max_abs_diff(&back, &h) < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let h = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig = 3, -1
        assert!(cholesky(&h).is_err());
    }

    #[test]
    fn spd_solve_matches() {
        let mut rng = Rng::new(21);
        let h = random_spd(&mut rng, 12, 1e-2);
        let x_true: Vec<f64> = (0..12).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let b = h.matvec(&x_true);
        let x = spd_solve(&h, &b).unwrap();
        for (a, b) in x.iter().zip(&x_true) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(22);
        let h = random_spd(&mut rng, 9, 1e-2);
        let inv = spd_inverse(&h).unwrap();
        let prod = h.matmul_naive(&inv);
        assert!(max_abs_diff(&prod, &Mat::eye(9)) < 1e-7);
    }
}
