//! Cholesky decomposition (H = L Lᵀ) and SPD solves. Used by the OPTQ
//! reference implementation (which Cholesky-decomposes H⁻¹), by the
//! pipeline's non-PD probe (`quantize_layer_robust`), and by tests.
//!
//! Above [`CHOL_BLOCK`] columns, [`cholesky`] runs a blocked right-looking
//! panel factorization (scalar diagonal panel → threaded per-row panel
//! solve → threaded trailing downdate via
//! `gemm::trailing_downdate_lower`), equal to the scalar kernel up to f64
//! summation order and bit-deterministic across thread counts. Measured
//! speedup: EXPERIMENTS.md §Perf 4.

use super::matrix::Mat;

/// Panel width of the blocked factorization; also the size threshold
/// below which [`cholesky`] stays on the scalar kernel.
pub const CHOL_BLOCK: usize = 64;

/// Cholesky H = L Lᵀ, L lower triangular. Errors on non-PD input.
/// Dispatches to the blocked threaded kernel above [`CHOL_BLOCK`] columns
/// (deterministic: the dispatch depends only on `n`).
pub fn cholesky(h: &Mat) -> crate::Result<Mat> {
    let t0 = std::time::Instant::now();
    let out = if h.rows <= CHOL_BLOCK {
        cholesky_scalar(h)
    } else {
        cholesky_blocked(h, CHOL_BLOCK)
    };
    crate::util::stagetimer::credit_factorize(t0.elapsed().as_secs_f64());
    out
}

/// The scalar left-looking kernel. Reference implementation for the
/// blocked path.
pub fn cholesky_scalar(h: &Mat) -> crate::Result<Mat> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = h[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    anyhow::bail!("matrix not positive definite at pivot {i} (s={s})");
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Blocked right-looking Cholesky with panel width `nb`: scalar
/// factorization of each diagonal panel, threaded per-row triangular
/// solve of the panel below it, then one threaded symmetric downdate of
/// the trailing submatrix (A22 −= L21·L21ᵀ, lower triangle only).
pub fn cholesky_blocked(h: &Mat, nb: usize) -> crate::Result<Mat> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let nb = nb.max(1);
    let mut l = Mat::zeros(n, n);
    // Working copy; trailing downdates write its lower triangle, the
    // panel steps read it (the initial matrix is symmetric).
    let mut a = h.clone();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb).min(n);
        let w = k1 - k0;
        // 1. Scalar Cholesky of the diagonal panel; contributions from
        // columns < k0 were already folded into `a` by trailing downdates.
        for i in k0..k1 {
            for j in k0..=i {
                let mut s = a[(i, j)];
                for k in k0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        anyhow::bail!("matrix not positive definite at pivot {i} (s={s})");
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        if k1 < n {
            // 2. Panel solve L21·L11ᵀ = A21: row i of L over columns
            // k0..k1 depends only on the diagonal panel and row i's own
            // earlier panel entries — rows solve independently in parallel.
            // Spawn workers only when the panel has real work
            // (~rows·w²/2 flops); small trailing panels run inline.
            let threads = if (n - k1) * w * w / 2 > 64 * 64 * 64 {
                crate::util::threadpool::default_threads()
            } else {
                1
            };
            let l11 = l.slice(k0, k1, k0, k1);
            let a_ref = &a;
            super::gemm::par_rows(&mut l, k1, n, threads, |i, lrow| {
                for j in k0..k1 {
                    let mut s = a_ref[(i, j)];
                    for k in k0..j {
                        s -= lrow[k] * l11[(j - k0, k - k0)];
                    }
                    lrow[j] = s / l11[(j - k0, j - k0)];
                }
            });
            // 3. Trailing downdate A22 −= L21·L21ᵀ.
            let rows_t = n - k1;
            let mut p = vec![0.0f64; rows_t * w];
            for i in k1..n {
                p[(i - k1) * w..(i - k1 + 1) * w].copy_from_slice(&l.row(i)[k0..k1]);
            }
            super::gemm::trailing_downdate_lower(&mut a, k1, &p, &p, w);
        }
        k0 = k1;
    }
    Ok(l)
}

/// Solve H x = b for SPD H via Cholesky.
pub fn spd_solve(h: &Mat, b: &[f64]) -> crate::Result<Vec<f64>> {
    let l = cholesky(h)?;
    let y = super::solve::forward_sub(&l, b, false);
    Ok(super::solve::backward_sub_t(&l, &y, false))
}

/// Inverse of an SPD matrix via Cholesky (solves against each basis vector).
pub fn spd_inverse(h: &Mat) -> crate::Result<Mat> {
    let n = h.rows;
    let l = cholesky(h)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = super::solve::forward_sub(&l, &e, false);
        let x = super::solve::backward_sub_t(&l, &y, false);
        inv.set_col(j, &x);
        e[j] = 0.0;
    }
    Ok(inv.symmetrize())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::random_spd;

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(20);
        for n in [1, 4, 17] {
            let h = random_spd(&mut rng, n, 1e-2);
            let l = cholesky(&h).unwrap();
            let back = l.matmul_naive(&l.transpose());
            assert!(max_abs_diff(&back, &h) < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let h = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig = 3, -1
        assert!(cholesky(&h).is_err());
    }

    #[test]
    fn blocked_matches_scalar_at_ragged_sizes() {
        // nb = 16 so 33/130 exercise partial panels; 130 also covers the
        // auto dispatch threshold.
        let mut rng = Rng::new(23);
        for n in [1usize, 7, 33, 130] {
            let h = random_spd(&mut rng, n, 1e-3);
            let s = cholesky_scalar(&h).unwrap();
            for nb in [16usize, 64] {
                let b = cholesky_blocked(&h, nb).unwrap();
                assert!(max_abs_diff(&b, &s) < 1e-8, "n={n} nb={nb}");
                let back = b.matmul_naive(&b.transpose());
                assert!(max_abs_diff(&back, &h) < 1e-8, "n={n} nb={nb} reconstruct");
            }
        }
        let h = random_spd(&mut rng, 130, 1e-3);
        let auto = cholesky(&h).unwrap();
        let forced = cholesky_blocked(&h, CHOL_BLOCK).unwrap();
        assert_eq!(auto.data, forced.data, "auto dispatch is the nb=64 kernel");
    }

    #[test]
    fn blocked_rejects_indefinite_in_late_panel() {
        // A negative direction deep in the trailing submatrix: the blocked
        // path must surface the same clean error as the scalar kernel,
        // not a NaN factor.
        let n = 100;
        let mut h = Mat::eye(n);
        h[(n - 1, n - 1)] = -0.5;
        let be = cholesky_blocked(&h, 16).unwrap_err();
        assert!(be.to_string().contains("not positive definite"), "{be}");
        assert!(cholesky_scalar(&h).is_err());
        assert!(cholesky(&h).is_err());
    }

    #[test]
    fn spd_solve_matches() {
        let mut rng = Rng::new(21);
        let h = random_spd(&mut rng, 12, 1e-2);
        let x_true: Vec<f64> = (0..12).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let b = h.matvec(&x_true);
        let x = spd_solve(&h, &b).unwrap();
        for (a, b) in x.iter().zip(&x_true) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(22);
        let h = random_spd(&mut rng, 9, 1e-2);
        let inv = spd_inverse(&h).unwrap();
        let prod = h.matmul_naive(&inv);
        assert!(max_abs_diff(&prod, &Mat::eye(9)) < 1e-7);
    }
}
