//! LDL factorizations.
//!
//! QuIP's Eq. (4) uses the *upper* unit-triangular form
//! `H = (U̇ + I) D (U̇ + I)ᵀ` with `U̇` strictly upper triangular — the
//! reversed-order variant of the textbook lower LDLᵀ. We provide both:
//! `ldl_lower` (H = L D Lᵀ) and `udu` via the reversal-permutation trick
//! (see DESIGN.md §4).
//!
//! Above [`LDL_BLOCK`] columns, [`ldl_lower`] dispatches to a blocked
//! right-looking panel factorization: the diagonal panel is factored with
//! the scalar kernel, the trailing rows' panel columns are filled by a
//! threaded per-row solve, and the trailing submatrix is downdated in one
//! threaded GEMM-shaped pass (`gemm::trailing_downdate_lower`). Results
//! match the scalar kernel up to f64 summation order and are
//! bit-deterministic across thread counts — see EXPERIMENTS.md §Perf 4
//! for the measured speedup over the scalar rank-1 downdate loop.

use super::matrix::Mat;

/// Panel width of the blocked factorization; also the size threshold
/// below which [`ldl_lower`] stays on the scalar kernel.
pub const LDL_BLOCK: usize = 64;

/// Lower LDLᵀ: H = L D Lᵀ with L unit lower triangular, D diagonal (≥ 0
/// for PSD inputs; tiny negative pivots from numerical PSD are clamped).
pub struct Ldl {
    pub l: Mat,
    pub d: Vec<f64>,
}

/// Upper "UDUᵀ": H = (U + I') … returned as `u` *unit* upper triangular
/// (diagonal = 1; the paper's U̇ is `u - I`) with diagonal `d`.
pub struct Udu {
    /// Unit upper triangular factor (U̇ + I in the paper's notation).
    pub u: Mat,
    pub d: Vec<f64>,
}

/// Compute the lower LDLᵀ of a symmetric PSD matrix. Pivots below
/// `tol · max_diag` are treated as zero (their L column below the diagonal
/// is zeroed) — the PSD completion standard trick. Dispatches to the
/// blocked threaded kernel above [`LDL_BLOCK`] columns; either way the
/// result is deterministic for a given size (the dispatch depends only on
/// `n`, and the blocked reduction order is thread-count-independent).
pub fn ldl_lower(h: &Mat, tol: f64) -> Ldl {
    let t0 = std::time::Instant::now();
    let out = if h.rows <= LDL_BLOCK {
        ldl_lower_scalar(h, tol)
    } else {
        ldl_lower_blocked(h, tol, LDL_BLOCK)
    };
    crate::util::stagetimer::credit_factorize(t0.elapsed().as_secs_f64());
    out
}

/// The scalar right-looking kernel (rank-1 trailing downdates). Reference
/// implementation for the blocked path; also the diagonal-panel kernel
/// inside [`ldl_lower_blocked`].
pub fn ldl_lower_scalar(h: &Mat, tol: f64) -> Ldl {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Mat::eye(n);
    let mut d = vec![0.0; n];
    // Working copy of the lower triangle, column by column (right-looking).
    let mut a = h.clone();
    let max_diag = (0..n).fold(0.0f64, |m, i| m.max(h[(i, i)].abs())).max(1e-300);
    for k in 0..n {
        let dk = a[(k, k)];
        if dk <= tol * max_diag {
            d[k] = dk.max(0.0);
            // Semi-definite pivot: column of L stays e_k.
            continue;
        }
        d[k] = dk;
        for i in (k + 1)..n {
            l[(i, k)] = a[(i, k)] / dk;
        }
        // Rank-1 downdate of the trailing submatrix.
        for i in (k + 1)..n {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            for j in (k + 1)..=i {
                let v = lik * l[(j, k)] * dk;
                a[(i, j)] -= v;
                if i != j {
                    a[(j, i)] -= v;
                }
            }
        }
    }
    Ldl { l, d }
}

/// Blocked right-looking LDLᵀ with panel width `nb`: scalar factorization
/// of each diagonal panel, threaded per-row panel solve for the rows
/// below, then one threaded symmetric downdate of the trailing submatrix.
/// Same pivot rule as [`ldl_lower_scalar`]; equal up to f64 summation
/// order.
pub fn ldl_lower_blocked(h: &Mat, tol: f64, nb: usize) -> Ldl {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let nb = nb.max(1);
    let mut l = Mat::eye(n);
    let mut d = vec![0.0; n];
    // Skipped-pivot flags (semi-definite columns): their L column stays
    // e_k, so they contribute nothing to solves or downdates.
    let mut skipped = vec![false; n];
    // Working copy; only the lower triangle (j ≤ i) is read or written
    // once the factorization starts (the initial matrix is symmetric).
    let mut a = h.clone();
    let max_diag = (0..n).fold(0.0f64, |m, i| m.max(h[(i, i)].abs())).max(1e-300);
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + nb).min(n);
        let w = k1 - k0;
        // 1. Scalar LDL of the diagonal panel (rows/cols k0..k1).
        for k in k0..k1 {
            let dk = a[(k, k)];
            if dk <= tol * max_diag {
                d[k] = dk.max(0.0);
                skipped[k] = true;
                continue;
            }
            d[k] = dk;
            for i in (k + 1)..k1 {
                l[(i, k)] = a[(i, k)] / dk;
            }
            for i in (k + 1)..k1 {
                let lik = l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                for j in (k + 1)..=i {
                    a[(i, j)] -= lik * l[(j, k)] * dk;
                }
            }
        }
        // 2. Panel solve for the trailing rows: row i of L over columns
        // k0..k1 depends only on the diagonal panel and on row i's own
        // earlier panel entries, so rows solve independently in parallel.
        if k1 < n {
            // Spawn workers only when the panel solve has real work
            // (~rows·w²/2 flops); small trailing panels run inline.
            let threads = if (n - k1) * w * w / 2 > 64 * 64 * 64 {
                crate::util::threadpool::default_threads()
            } else {
                1
            };
            let l11 = l.slice(k0, k1, k0, k1);
            let a_ref = &a;
            let d_ref = &d;
            let skipped_ref = &skipped;
            super::gemm::par_rows(&mut l, k1, n, threads, |i, lrow| {
                for j in k0..k1 {
                    if skipped_ref[j] {
                        lrow[j] = 0.0;
                        continue;
                    }
                    let mut s = a_ref[(i, j)];
                    for k in k0..j {
                        s -= lrow[k] * d_ref[k] * l11[(j - k0, k - k0)];
                    }
                    lrow[j] = s / d_ref[j];
                }
            });
            // 3. Trailing downdate A22 −= P·diag(d_panel)·Pᵀ with
            // P = L[k1.., k0..k1], packed contiguously for unit-stride dots.
            let rows_t = n - k1;
            let mut p = vec![0.0f64; rows_t * w];
            let mut pd = vec![0.0f64; rows_t * w];
            for i in k1..n {
                let lrow = l.row(i);
                for (c, k) in (k0..k1).enumerate() {
                    let v = lrow[k];
                    p[(i - k1) * w + c] = v;
                    pd[(i - k1) * w + c] = v * d[k];
                }
            }
            super::gemm::trailing_downdate_lower(&mut a, k1, &pd, &p, w);
        }
        k0 = k1;
    }
    Ldl { l, d }
}

/// The paper's factorization: H = U D Uᵀ with U *unit upper* triangular.
///
/// Implementation: with P the index-reversal permutation, `P H P = L D' Lᵀ`
/// (lower LDL); then `U = P L P` is unit upper and `D = P D' P`. Inherits
/// [`ldl_lower`]'s scalar/blocked dispatch.
pub fn udu(h: &Mat, tol: f64) -> Udu {
    udu_via(h, tol, ldl_lower)
}

/// [`udu`] pinned to the scalar LDL kernel — the baseline leg of
/// blocked-vs-scalar equivalence tests and of `quip sweep quant`.
pub fn udu_scalar(h: &Mat, tol: f64) -> Udu {
    udu_via(h, tol, ldl_lower_scalar)
}

fn udu_via(h: &Mat, tol: f64, ldl: fn(&Mat, f64) -> Ldl) -> Udu {
    let n = h.rows;
    let rev: Vec<usize> = (0..n).rev().collect();
    let hp = h.permute_sym(&rev);
    let Ldl { l, d } = ldl(&hp, tol);
    let u = l.permute_sym(&rev);
    let mut dd = vec![0.0; n];
    for i in 0..n {
        dd[i] = d[n - 1 - i];
    }
    Udu { u, d: dd }
}

impl Udu {
    /// Reconstruct H = U D Uᵀ (for testing / diagnostics).
    pub fn reconstruct(&self) -> Mat {
        let n = self.u.rows;
        let ud = self.u.scale_cols(&self.d);
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in i.max(j)..n {
                    s += ud[(i, k)] * self.u[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    /// The strictly-upper feedback matrix U̇ = U − I used by LDLQ.
    pub fn strictly_upper(&self) -> Mat {
        let mut m = self.u.clone();
        for i in 0..m.rows {
            m[(i, i)] = 0.0;
        }
        m
    }

    /// tr(D) — the quantity Theorem 1 bounds the proxy loss with.
    pub fn trace_d(&self) -> f64 {
        self.d.iter().sum()
    }
}

impl Ldl {
    pub fn reconstruct(&self) -> Mat {
        let ld = self.l.scale_cols(&self.d);
        ld.matmul_naive(&self.l.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::random_spd;

    #[test]
    fn ldl_reconstructs_spd() {
        let mut rng = Rng::new(10);
        for n in [1, 2, 5, 16, 40] {
            let h = random_spd(&mut rng, n, 1e-3);
            let f = ldl_lower(&h, 1e-12);
            assert!(
                max_abs_diff(&f.reconstruct(), &h) < 1e-8,
                "n={n}"
            );
            assert!(f.d.iter().all(|&d| d >= 0.0));
        }
    }

    #[test]
    fn udu_reconstructs_spd() {
        let mut rng = Rng::new(11);
        for n in [1, 3, 8, 33] {
            let h = random_spd(&mut rng, n, 1e-3);
            let f = udu(&h, 1e-12);
            assert!(max_abs_diff(&f.reconstruct(), &h) < 1e-8, "n={n}");
            // u is unit upper triangular
            for i in 0..n {
                assert!((f.u[(i, i)] - 1.0).abs() < 1e-12);
                for j in 0..i {
                    assert_eq!(f.u[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn udu_handles_low_rank() {
        // H = v vᵀ is rank 1 PSD.
        let v = [1.0, -2.0, 0.5, 3.0];
        let h = Mat::from_fn(4, 4, |i, j| v[i] * v[j]);
        let f = udu(&h, 1e-12);
        assert!(max_abs_diff(&f.reconstruct(), &h) < 1e-8);
        assert!(f.d.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn trace_d_leq_trace_h() {
        // tr(D) ≤ tr(H) for any PSD H (§3.2): the ratio drives LDLQ's gain.
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let h = random_spd(&mut rng, 24, 1e-3);
            let f = udu(&h, 1e-12);
            assert!(f.trace_d() <= h.trace() + 1e-9);
        }
    }

    #[test]
    fn diagonal_h_gives_d_equal_diag() {
        let h = Mat::diag(&[3.0, 1.0, 4.0, 1.5]);
        let f = udu(&h, 1e-12);
        assert_eq!(f.d, vec![3.0, 1.0, 4.0, 1.5]);
        assert!(max_abs_diff(&f.u, &Mat::eye(4)) < 1e-12);
    }

    #[test]
    fn blocked_matches_scalar_at_ragged_sizes() {
        // nb = 16 so 1/7 hit the single-panel path and 33/130 exercise
        // partial trailing panels; 130 also exceeds LDL_BLOCK, covering the
        // auto dispatch (compared against blocked(64) below).
        let mut rng = Rng::new(40);
        for n in [1usize, 7, 33, 130] {
            let h = random_spd(&mut rng, n, 1e-3);
            let s = ldl_lower_scalar(&h, 1e-12);
            for nb in [16usize, 64] {
                let b = ldl_lower_blocked(&h, 1e-12, nb);
                assert!(max_abs_diff(&b.l, &s.l) < 1e-7, "n={n} nb={nb} L");
                for (x, y) in b.d.iter().zip(&s.d) {
                    assert!((x - y).abs() < 1e-7 * x.abs().max(1.0), "n={n} nb={nb} d");
                }
                assert!(max_abs_diff(&b.reconstruct(), &h) < 1e-7, "n={n} nb={nb}");
            }
        }
        // Auto dispatch at n > LDL_BLOCK is exactly the nb = LDL_BLOCK kernel.
        let h = random_spd(&mut rng, 130, 1e-3);
        let auto = ldl_lower(&h, 1e-12);
        let forced = ldl_lower_blocked(&h, 1e-12, LDL_BLOCK);
        assert_eq!(auto.l.data, forced.l.data);
        assert_eq!(auto.d, forced.d);
    }

    #[test]
    fn blocked_handles_low_rank_psd() {
        // Rank-5 PSD at n = 130: most pivots hit the semi-definite skip
        // path inside blocked panels — the L columns must stay e_k and the
        // reconstruction must still hold.
        let mut rng = Rng::new(41);
        let h = crate::util::testkit::random_hessian(&mut rng, 130, 5, 0.0);
        let f = ldl_lower_blocked(&h, 1e-10, 16);
        assert!(f.d.iter().all(|&d| d >= 0.0));
        let scale = h.max_abs().max(1.0);
        assert!(max_abs_diff(&f.reconstruct(), &h) < 1e-7 * scale);
        let s = ldl_lower_scalar(&h, 1e-10);
        assert!(max_abs_diff(&f.reconstruct(), &s.reconstruct()) < 1e-7 * scale);
    }

    #[test]
    fn udu_blocked_matches_scalar() {
        let mut rng = Rng::new(42);
        for n in [7usize, 33, 130] {
            let h = random_spd(&mut rng, n, 1e-3);
            let a = udu(&h, 1e-12); // auto: blocked at 130
            let b = udu_scalar(&h, 1e-12);
            assert!(max_abs_diff(&a.u, &b.u) < 1e-7, "n={n}");
            for (x, y) in a.d.iter().zip(&b.d) {
                assert!((x - y).abs() < 1e-7 * x.abs().max(1.0), "n={n}");
            }
            // Unit-upper structure survives the blocked path.
            for i in 0..n {
                assert!((a.u[(i, i)] - 1.0).abs() < 1e-12);
                for j in 0..i {
                    assert_eq!(a.u[(i, j)], 0.0);
                }
            }
        }
    }
}
