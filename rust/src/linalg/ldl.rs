//! LDL factorizations.
//!
//! QuIP's Eq. (4) uses the *upper* unit-triangular form
//! `H = (U̇ + I) D (U̇ + I)ᵀ` with `U̇` strictly upper triangular — the
//! reversed-order variant of the textbook lower LDLᵀ. We provide both:
//! `ldl_lower` (H = L D Lᵀ) and `udu` via the reversal-permutation trick
//! (see DESIGN.md §4).

use super::matrix::Mat;

/// Lower LDLᵀ: H = L D Lᵀ with L unit lower triangular, D diagonal (≥ 0
/// for PSD inputs; tiny negative pivots from numerical PSD are clamped).
pub struct Ldl {
    pub l: Mat,
    pub d: Vec<f64>,
}

/// Upper "UDUᵀ": H = (U + I') … returned as `u` *unit* upper triangular
/// (diagonal = 1; the paper's U̇ is `u - I`) with diagonal `d`.
pub struct Udu {
    /// Unit upper triangular factor (U̇ + I in the paper's notation).
    pub u: Mat,
    pub d: Vec<f64>,
}

/// Compute the lower LDLᵀ of a symmetric PSD matrix. Pivots below
/// `tol · max_diag` are treated as zero (their L column below the diagonal
/// is zeroed) — the PSD completion standard trick.
pub fn ldl_lower(h: &Mat, tol: f64) -> Ldl {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Mat::eye(n);
    let mut d = vec![0.0; n];
    // Working copy of the lower triangle, column by column (right-looking).
    let mut a = h.clone();
    let max_diag = (0..n).fold(0.0f64, |m, i| m.max(h[(i, i)].abs())).max(1e-300);
    for k in 0..n {
        let dk = a[(k, k)];
        if dk <= tol * max_diag {
            d[k] = dk.max(0.0);
            // Semi-definite pivot: column of L stays e_k.
            continue;
        }
        d[k] = dk;
        for i in (k + 1)..n {
            l[(i, k)] = a[(i, k)] / dk;
        }
        // Rank-1 downdate of the trailing submatrix.
        for i in (k + 1)..n {
            let lik = l[(i, k)];
            if lik == 0.0 {
                continue;
            }
            for j in (k + 1)..=i {
                let v = lik * l[(j, k)] * dk;
                a[(i, j)] -= v;
                if i != j {
                    a[(j, i)] -= v;
                }
            }
        }
    }
    Ldl { l, d }
}

/// The paper's factorization: H = U D Uᵀ with U *unit upper* triangular.
///
/// Implementation: with P the index-reversal permutation, `P H P = L D' Lᵀ`
/// (lower LDL); then `U = P L P` is unit upper and `D = P D' P`.
pub fn udu(h: &Mat, tol: f64) -> Udu {
    let n = h.rows;
    let rev: Vec<usize> = (0..n).rev().collect();
    let hp = h.permute_sym(&rev);
    let Ldl { l, d } = ldl_lower(&hp, tol);
    let u = l.permute_sym(&rev);
    let mut dd = vec![0.0; n];
    for i in 0..n {
        dd[i] = d[n - 1 - i];
    }
    Udu { u, d: dd }
}

impl Udu {
    /// Reconstruct H = U D Uᵀ (for testing / diagnostics).
    pub fn reconstruct(&self) -> Mat {
        let n = self.u.rows;
        let ud = self.u.scale_cols(&self.d);
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in i.max(j)..n {
                    s += ud[(i, k)] * self.u[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    /// The strictly-upper feedback matrix U̇ = U − I used by LDLQ.
    pub fn strictly_upper(&self) -> Mat {
        let mut m = self.u.clone();
        for i in 0..m.rows {
            m[(i, i)] = 0.0;
        }
        m
    }

    /// tr(D) — the quantity Theorem 1 bounds the proxy loss with.
    pub fn trace_d(&self) -> f64 {
        self.d.iter().sum()
    }
}

impl Ldl {
    pub fn reconstruct(&self) -> Mat {
        let ld = self.l.scale_cols(&self.d);
        ld.matmul_naive(&self.l.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::max_abs_diff;
    use crate::util::rng::Rng;
    use crate::util::testkit::random_spd;

    #[test]
    fn ldl_reconstructs_spd() {
        let mut rng = Rng::new(10);
        for n in [1, 2, 5, 16, 40] {
            let h = random_spd(&mut rng, n, 1e-3);
            let f = ldl_lower(&h, 1e-12);
            assert!(
                max_abs_diff(&f.reconstruct(), &h) < 1e-8,
                "n={n}"
            );
            assert!(f.d.iter().all(|&d| d >= 0.0));
        }
    }

    #[test]
    fn udu_reconstructs_spd() {
        let mut rng = Rng::new(11);
        for n in [1, 3, 8, 33] {
            let h = random_spd(&mut rng, n, 1e-3);
            let f = udu(&h, 1e-12);
            assert!(max_abs_diff(&f.reconstruct(), &h) < 1e-8, "n={n}");
            // u is unit upper triangular
            for i in 0..n {
                assert!((f.u[(i, i)] - 1.0).abs() < 1e-12);
                for j in 0..i {
                    assert_eq!(f.u[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn udu_handles_low_rank() {
        // H = v vᵀ is rank 1 PSD.
        let v = [1.0, -2.0, 0.5, 3.0];
        let h = Mat::from_fn(4, 4, |i, j| v[i] * v[j]);
        let f = udu(&h, 1e-12);
        assert!(max_abs_diff(&f.reconstruct(), &h) < 1e-8);
        assert!(f.d.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn trace_d_leq_trace_h() {
        // tr(D) ≤ tr(H) for any PSD H (§3.2): the ratio drives LDLQ's gain.
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let h = random_spd(&mut rng, 24, 1e-3);
            let f = udu(&h, 1e-12);
            assert!(f.trace_d() <= h.trace() + 1e-9);
        }
    }

    #[test]
    fn diagonal_h_gives_d_equal_diag() {
        let h = Mat::diag(&[3.0, 1.0, 4.0, 1.5]);
        let f = udu(&h, 1e-12);
        assert_eq!(f.d, vec![3.0, 1.0, 4.0, 1.5]);
        assert!(max_abs_diff(&f.u, &Mat::eye(4)) < 1e-12);
    }
}
