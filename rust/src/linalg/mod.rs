//! Dense linear-algebra substrate (no BLAS/LAPACK available offline).
//!
//! Everything QuIP's math needs: a row-major `f64` matrix, blocked and
//! threaded GEMM, the UDUᵀ ("reverse LDL") factorization the paper's
//! Eq. (4) uses, Cholesky, a cyclic-Jacobi symmetric eigensolver,
//! Householder QR, Haar-random orthogonal sampling, Kronecker-structured
//! fast orthogonal multiplication, and triangular solves.

pub mod matrix;
pub mod gemm;
pub mod ldl;
pub mod chol;
pub mod eigen;
pub mod orthogonal;
pub mod kron;
pub mod solve;

pub use matrix::Mat;
pub use kron::KronOrtho;
