//! Dense linear-algebra substrate (no BLAS/LAPACK available offline).
//!
//! Everything QuIP's math needs: a row-major `f64` matrix, blocked and
//! threaded GEMM and SYRK (rank-k AᵀA) kernels, the UDUᵀ ("reverse LDL")
//! factorization the paper's Eq. (4) uses and Cholesky — both blocked and
//! threaded above one panel (EXPERIMENTS.md §Perf 4) — a cyclic-Jacobi
//! symmetric eigensolver,
//! Householder QR, Haar-random orthogonal sampling, the pluggable
//! incoherence-transform subsystem ([`transform::Transform`]) with its
//! Kronecker ([`kron`]) and randomized-Hadamard ([`hadamard`]) backends,
//! and triangular solves.

pub mod matrix;
pub mod gemm;
pub mod ldl;
pub mod chol;
pub mod eigen;
pub mod orthogonal;
pub mod kron;
pub mod hadamard;
pub mod transform;
pub mod solve;

pub use hadamard::RandomizedHadamard;
pub use kron::{KronOrtho, KronTransform};
pub use matrix::Mat;
pub use transform::{make_transform, Transform, TransformKind};
