//! Artifact registry: parses `artifacts/manifest.json` (written by
//! aot.py) into typed specs the engines use to marshal inputs in the
//! exact order the lowered HLO expects.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Parameter (or "tokens") name.
    pub name: String,
    /// Qparam field ("words", "rowscale", …) or empty for plain params.
    pub field: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub kind: String,
    pub model: String,
    pub bits: u32,
    pub incoherent: bool,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<InputSpec>,
}

#[derive(Debug)]
pub struct Registry {
    pub root: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Registry {
    pub fn load(root: &Path) -> crate::Result<Registry> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("no manifest at {root:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?
        {
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|i| {
                    Ok(InputSpec {
                        name: i.req_str("name")?.to_string(),
                        field: i.get("field").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                        shape: i
                            .req("shape")?
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|x| x.as_usize())
                            .collect(),
                        dtype: i.req_str("dtype")?.to_string(),
                    })
                })
                .collect::<crate::Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                file: root.join(a.req_str("file")?),
                kind: a.req_str("kind")?.to_string(),
                model: a
                    .get("model")
                    .and_then(|m| m.as_str())
                    .unwrap_or("")
                    .to_string(),
                bits: a.get("bits").and_then(|b| b.as_f64()).unwrap_or(0.0) as u32,
                incoherent: a
                    .get("incoherent")
                    .and_then(|b| b.as_bool())
                    .unwrap_or(false),
                batch: a.get("batch").and_then(|b| b.as_usize()).unwrap_or(1),
                seq: a.get("seq").and_then(|b| b.as_usize()).unwrap_or(0),
                inputs,
            });
        }
        Ok(Registry {
            root: root.to_path_buf(),
            artifacts,
        })
    }

    pub fn find_fp32(&self, model: &str, batch: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "fp32" && a.model == model && a.batch == batch)
    }

    pub fn find_quant(&self, model: &str, bits: u32) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "quant" && a.model == model && a.bits == bits)
    }

    pub fn find_kernel(&self, bits: u32) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "kernel" && a.bits == bits)
    }

    /// Checkpoint path for a model name.
    pub fn checkpoint(&self, model: &str) -> PathBuf {
        self.root.join("models").join(format!("{model}.ckpt"))
    }

    /// Data split path.
    pub fn split(&self, name: &str) -> PathBuf {
        self.root.join("data").join(format!("{name}.bin"))
    }

    pub fn tasks(&self, name: &str) -> PathBuf {
        self.root.join("data").join(format!("tasks_{name}.json"))
    }

    pub fn vocab(&self) -> PathBuf {
        self.root.join("data").join("vocab.json")
    }
}

/// The default artifacts directory (repo-root/artifacts), overridable via
/// QUIP_ARTIFACTS.
pub fn default_root() -> PathBuf {
    std::env::var("QUIP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let dir = std::env::temp_dir().join("quip_reg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
              {"kind": "fp32", "model": "s0", "batch": 1, "seq": 128,
               "inputs": [{"name": "tokens", "field": "", "shape": [1, 128], "dtype": "i32"}],
               "file": "hlo/x.hlo.txt"},
              {"kind": "quant", "model": "s0", "bits": 2, "incoherent": true,
               "batch": 1, "seq": 128, "inputs": [], "file": "hlo/q.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        let r = Registry::load(&dir).unwrap();
        assert_eq!(r.artifacts.len(), 2);
        assert!(r.find_fp32("s0", 1).is_some());
        assert!(r.find_fp32("s0", 9).is_none());
        let q = r.find_quant("s0", 2).unwrap();
        assert!(q.incoherent);
        assert_eq!(r.checkpoint("s0").file_name().unwrap(), "s0.ckpt");
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let dir = std::env::temp_dir().join("quip_reg_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        let err = Registry::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
