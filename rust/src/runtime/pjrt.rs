//! Thin wrapper over the `xla` crate: PJRT CPU client, HLO-text loading
//! (`HloModuleProto::from_text_file` — the interchange that survives
//! xla_extension 0.5.1's 32-bit-id limit), compilation, and execution
//! with typed input marshalling.

use std::path::Path;

/// Typed host-side input buffers (marshalled to XLA literals).
pub enum Input {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    U8(Vec<u8>, Vec<usize>),
}

impl Input {
    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let lit = match self {
            Input::F32(data, dims) => {
                let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    dims,
                    &bytes,
                )?
            }
            Input::I32(data, dims) => {
                let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    dims,
                    &bytes,
                )?
            }
            Input::U8(data, dims) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                dims,
                data,
            )?,
        };
        Ok(lit)
    }

    pub fn element_count(&self) -> usize {
        match self {
            Input::F32(d, _) => d.len(),
            Input::I32(d, _) => d.len(),
            Input::U8(d, _) => d.len(),
        }
    }
}

/// The PJRT CPU client (one per process; compile executables through it).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> crate::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> crate::Result<Executable> {
        anyhow::ensure!(path.exists(), "HLO artifact not found: {path:?}");
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().unwrap()).map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow_xla)?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled XLA executable. The lowered functions all return a 1-tuple
/// (aot.py lowers with return_tuple=True), unwrapped here.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with pre-marshalled literals (hot path: callers cache
    /// literals for static inputs like weights).
    pub fn execute_literals(&self, literals: &[xla::Literal]) -> crate::Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(literals).map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let out = lit.to_tuple1().map_err(anyhow_xla)?;
        out.to_vec::<f32>().map_err(anyhow_xla)
    }

    /// Execute over borrowed literals (hot path — avoids cloning cached
    /// weight literals).
    pub fn execute_borrowed(&self, lits: &[&xla::Literal]) -> crate::Result<Vec<f32>> {
        let result = self.exe.execute::<&xla::Literal>(lits).map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let out = lit.to_tuple1().map_err(anyhow_xla)?;
        out.to_vec::<f32>().map_err(anyhow_xla)
    }

    /// Execute with typed host inputs.
    pub fn execute(&self, inputs: &[Input]) -> crate::Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<crate::Result<_>>()?;
        self.execute_literals(&literals)
    }

    /// Marshal inputs once (for caching static operands).
    pub fn marshal(inputs: &[Input]) -> crate::Result<Vec<xla::Literal>> {
        inputs.iter().map(|i| i.to_literal()).collect()
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have produced HLO files; they
    /// self-skip otherwise so plain `cargo test` stays hermetic.
    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn kernel_artifact_matches_rust_unpack() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let rt = PjrtRuntime::cpu().unwrap();
        let path = dir.join("hlo/kernel_q2_m512_n512_t16.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: kernel artifact missing");
            return;
        }
        let exe = rt.load(&path).unwrap();
        // Random 2-bit codes, packed LSB-first like python's pack_codes.
        let mut rng = crate::util::rng::Rng::new(5);
        let (m, n, t, bits) = (512usize, 512usize, 16usize, 2u32);
        let codes: Vec<u8> = (0..m * n).map(|_| rng.below(4) as u8).collect();
        let per = 32 / bits as usize;
        let nw = n.div_ceil(per);
        let mut words = vec![0i32; m * nw];
        for i in 0..m {
            for j in 0..n {
                let w = j / per;
                let k = j % per;
                words[i * nw + w] |= (codes[i * n + j] as i32) << (k * bits as usize);
            }
        }
        let x: Vec<f32> = (0..t * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let out = exe
            .execute(&[
                Input::I32(words, vec![m, nw]),
                Input::F32(x.clone(), vec![t, n]),
            ])
            .unwrap();
        assert_eq!(out.len(), t * m);
        // Compare against rust-side reference.
        for tt in 0..t {
            for i in (0..m).step_by(97) {
                let mut s = 0.0f64;
                for j in 0..n {
                    s += codes[i * n + j] as f64 * x[tt * n + j] as f64;
                }
                let got = out[tt * m + i] as f64;
                assert!(
                    (got - s).abs() < 1e-2 * s.abs().max(1.0),
                    "mismatch at ({tt},{i}): {got} vs {s}"
                );
            }
        }
    }
}
