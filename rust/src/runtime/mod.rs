//! PJRT runtime: loads the HLO-text artifacts `python/compile/aot.py`
//! produced and executes them on the CPU PJRT client via the `xla` crate.
//! This is the only place the process touches XLA; everything upstream of
//! `make artifacts` is build-time Python, everything downstream is Rust.

pub mod pjrt;
pub mod registry;

pub use pjrt::{Executable, Input, PjrtRuntime};
pub use registry::{ArtifactSpec, InputSpec, Registry};
