//! Paged vs contiguous KV-cache decode: tracks the KV-read overhead of
//! the block-table indirection (per-page `for_each_run` visits + pool
//! mutex) against the flat contiguous baseline, batch 1 and batched.
//! Uses random checkpoints so `cargo bench` always runs; the interesting
//! number is the paged/contig ratio, which should stay close to 1.0 —
//! the linears dominate and the KV walk is a small fraction of a step.

use quip::engine::native::{decode_step_batch, decode_step_with, FpLinears, LinearOps};
use quip::model::kvpool::KvPool;
use quip::model::weights::Checkpoint;
use quip::model::{KvCache, ModelConfig, Transformer, DEFAULT_PAGE_TOKENS};

/// Per-token latency for a single sequence decoded `tokens` steps.
fn tok_latency(model: &Transformer, lin: &dyn LinearOps, cache: &mut KvCache, tokens: usize) -> f64 {
    for t in 0..8u32 {
        decode_step_with(model, lin, cache, t + 1);
    }
    let t0 = std::time::Instant::now();
    let mut tok = 1u32;
    for _ in 0..tokens {
        if cache.len() >= model.cfg.max_seq {
            cache.reset();
        }
        let logits = decode_step_with(model, lin, cache, tok);
        tok = (logits[3].abs() as u32 % 250) + 1;
    }
    t0.elapsed().as_secs_f64() / tokens as f64
}

/// Per-token latency across a batch of independent sequences stepped
/// together for `steps` rounds (batch × steps tokens total).
fn batch_latency(
    model: &Transformer,
    lin: &dyn LinearOps,
    caches: &mut [KvCache],
    steps: usize,
) -> f64 {
    let bsz = caches.len();
    let vocab = model.cfg.vocab;
    let mut toks: Vec<u32> = (0..bsz as u32).map(|b| b % 250 + 1).collect();
    let mut run = |rounds: usize, timed: bool| -> f64 {
        let t0 = std::time::Instant::now();
        for _ in 0..rounds {
            if caches.iter().any(|c| c.len() >= model.cfg.max_seq) {
                for c in caches.iter_mut() {
                    c.reset();
                }
            }
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = decode_step_batch(model, lin, &mut refs, &toks);
            for (b, t) in toks.iter_mut().enumerate() {
                *t = (logits[b * vocab + 3].abs() as u32 % 250) + 1;
            }
        }
        if timed {
            t0.elapsed().as_secs_f64() / (rounds * bsz) as f64
        } else {
            0.0
        }
    };
    run(4, false);
    run(steps, true)
}

fn main() {
    let tokens = 96;
    println!("Paged-KV decode overhead (native fp32 engine)\n");
    for name in ["s0", "s1"] {
        let cfg = ModelConfig::by_name(name).unwrap();
        let ck = Checkpoint::random(&cfg, 1);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        let lin = FpLinears { model: &model };

        // Batch 1: contiguous slab vs one paged sequence.
        let mut contig = model.new_cache();
        let t_c = tok_latency(&model, &lin, &mut contig, tokens);
        let pool = KvPool::shared(
            cfg.n_layers,
            cfg.d_model,
            cfg.max_seq.div_ceil(DEFAULT_PAGE_TOKENS) + 1,
            DEFAULT_PAGE_TOKENS,
        );
        let mut paged = model.new_paged_cache(&pool);
        let t_p = tok_latency(&model, &lin, &mut paged, tokens);
        println!(
            "bench  paged_decode_{name}_b1    contig {:8.3}ms  paged {:8.3}ms  (paged/contig {:.3}x)",
            t_c * 1e3,
            t_p * 1e3,
            t_p / t_c
        );

        // Batch 8: the serving shape — ragged positions, shared pool.
        let bsz = 8usize;
        let steps = tokens / 2;
        let mut contigs: Vec<KvCache> = (0..bsz).map(|_| model.new_cache()).collect();
        let t_cb = batch_latency(&model, &lin, &mut contigs, steps);
        let pool = KvPool::shared(
            cfg.n_layers,
            cfg.d_model,
            bsz * (cfg.max_seq.div_ceil(DEFAULT_PAGE_TOKENS) + 1),
            DEFAULT_PAGE_TOKENS,
        );
        let mut pageds: Vec<KvCache> = (0..bsz).map(|_| model.new_paged_cache(&pool)).collect();
        let t_pb = batch_latency(&model, &lin, &mut pageds, steps);
        println!(
            "bench  paged_decode_{name}_b{bsz}    contig {:8.3}ms  paged {:8.3}ms  (paged/contig {:.3}x)",
            t_cb * 1e3,
            t_pb * 1e3,
            t_pb / t_cb
        );
        let snap = {
            drop(pageds);
            pool.lock().unwrap().snapshot()
        };
        println!(
            "       pool: peak {} pages ({} total), cow {}, all released: {}",
            snap.peak_pages,
            snap.pages_total,
            snap.cow_copies,
            snap.pages_used == 0
        );
    }
    println!("\ntarget: paged/contig ≈ 1.0x — the block-table walk must not tax decode.");
}
