//! `cargo bench --bench tables` — quick-mode regeneration of the
//! compute-bound paper tables (the full versions run via `quip table all`).
//! Keeps every table's code path exercised under the bench harness.

use quip::util::cli::Args;

fn main() {
    let args = Args::parse(
        ["--fast".to_string()]
            .into_iter()
            .chain(std::env::args().skip(1).filter(|a| a != "--bench")),
    );
    // Artifact-independent tables/figures always run:
    quip::harness::run_table("optq", &Args::parse(["--n".into(), "400".into(), "--m".into(), "256".into()])).unwrap();
    println!();
    quip::harness::run_figure("4", &args).unwrap();

    // Artifact-dependent tables run when `make artifacts` has been done.
    let have_artifacts =
        quip::runtime::Registry::load(&quip::runtime::registry::default_root()).is_ok();
    if have_artifacts {
        for t in ["6", "14", "4"] {
            println!("\n================ table {t} (fast) ================");
            quip::harness::run_table(t, &args).unwrap();
        }
    } else {
        println!("\n(make artifacts to enable the model-based tables here)");
    }
}
