//! GEMM / matvec substrate benchmarks (cargo bench --bench gemm).
//! Baseline vs blocked+threaded f64 GEMM, f32 weight matvec, and the fast
//! Kronecker multiply vs its dense equivalent.

use quip::linalg::gemm::{matmul, sgemm_bt, syrk};
use quip::linalg::{KronOrtho, Mat};
use quip::util::rng::Rng;
use quip::util::timer::{bench_budget, report};

fn main() {
    let mut rng = Rng::new(1);

    for n in [128usize, 256, 512] {
        let a = Mat::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let b = Mat::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let s_naive = bench_budget(1, 0.5, || a.matmul_naive(&b));
        let s_fast = bench_budget(1, 0.5, || matmul(&a, &b));
        report(&format!("gemm_f64_naive_{n}"), &s_naive);
        report(&format!("gemm_f64_blocked_{n}"), &s_fast);
        let gflops = 2.0 * (n as f64).powi(3) / s_fast.p50 / 1e9;
        println!("  blocked {n}: {gflops:.2} GFLOP/s (speedup {:.2}x)", s_naive.p50 / s_fast.p50);
    }

    // SYRK (AᵀA) rank-k kernel — the Hessian-accumulation substrate
    // (EXPERIMENTS.md §Perf 4) — vs composing transpose + naive GEMM.
    for n in [256usize, 1024] {
        let a = Mat::from_fn(2 * n, n, |_, _| rng.uniform(-1.0, 1.0));
        let s_syrk = bench_budget(1, 0.5, || syrk(&a));
        let s_naive = bench_budget(1, 0.5, || a.transpose().matmul_naive(&a));
        report(&format!("syrk_f64_{n}"), &s_syrk);
        report(&format!("syrk_naive_{n}"), &s_naive);
        println!("  syrk {n}: {:.2}x over transpose+naive", s_naive.p50 / s_syrk.p50);
    }

    // f32 weight matvec (decode shape): y[1,out] = x[1,in] · Wᵀ
    for (m, n) in [(512usize, 512usize), (1024, 256), (1536, 384)] {
        let w: Vec<f32> = (0..m * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let mut y = vec![0.0f32; m];
        let s = bench_budget(3, 0.4, || sgemm_bt(1, n, m, &x, &w, &mut y));
        report(&format!("matvec_f32_{m}x{n}"), &s);
    }

    // fast Kronecker multiply vs dense n×n matvec
    for n in [256usize, 1024] {
        let k = KronOrtho::from_seed(3, n);
        let dense = k.dense();
        let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let s_fast = bench_budget(3, 0.3, || k.apply_vec(&x));
        let s_dense = bench_budget(3, 0.3, || dense.matvec(&x));
        report(&format!("kron_fast_{n}"), &s_fast);
        report(&format!("kron_dense_{n}"), &s_dense);
        println!(
            "  kron {n}: fast multiply is {:.1}x cheaper than dense (paper: O(n√n) vs O(n²))",
            s_dense.p50 / s_fast.p50
        );
    }
}
