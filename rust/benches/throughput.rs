//! End-to-end decode throughput (paper Table 4): per-token latency of the
//! native engine with fp32 weights, OPTQ-style quantized weights (no
//! incoherence at inference) and QuIP quantized weights (Kronecker
//! incoherence transform on the hot path), plus the PJRT kernel artifact
//! when present. Uses a *random* checkpoint when artifacts are absent so
//! `cargo bench` always runs.

use quip::engine::native::{decode_step_with, FpLinears, LinearOps, QuantLinears};
use quip::model::quantized::QuantizedModel;
use quip::model::weights::Checkpoint;
use quip::model::{ModelConfig, Transformer};
use quip::quant::packed::QuantizedLayer;
use quip::quant::{quantize_layer, Method, Processing, QuantConfig};
use quip::util::rng::Rng;
use quip::util::testkit::random_hessian;

fn quantize(model: &Transformer, bits: u32, processing: Processing) -> QuantizedModel {
    let mut rng = Rng::new(3);
    let layers = model
        .cfg
        .linear_specs()
        .into_iter()
        .map(|spec| {
            let wdata = model.get_weight(&spec.name).unwrap();
            let w = quip::linalg::Mat {
                rows: spec.out_dim,
                cols: spec.in_dim,
                data: wdata.iter().map(|&x| x as f64).collect(),
            };
            let h = random_hessian(&mut rng, spec.in_dim, 8, 1e-2);
            let out = quantize_layer(
                &w,
                &h,
                &QuantConfig {
                    bits,
                    method: Method::Nearest, // rounding method is irrelevant
                    processing: processing.clone(), // for *throughput*
                    ..Default::default()
                },
                5,
            );
            QuantizedLayer::from_codes(&spec.name, &out.codes, bits, out.post)
        })
        .collect();
    QuantizedModel {
        config: model.cfg.clone(),
        bits,
        recipe: "bench".into(),
        layers,
    }
}

fn tok_latency(model: &Transformer, lin: &dyn LinearOps, tokens: usize) -> f64 {
    let mut cache = model.new_cache();
    for t in 0..8u32 {
        decode_step_with(model, lin, &mut cache, t + 1);
    }
    let t0 = std::time::Instant::now();
    let mut tok = 1u32;
    for _ in 0..tokens {
        if cache.len() >= model.cfg.max_seq {
            cache.reset();
        }
        let logits = decode_step_with(model, lin, &mut cache, tok);
        tok = (logits[3].abs() as u32 % 250) + 1;
    }
    t0.elapsed().as_secs_f64() / tokens as f64
}

fn main() {
    let tokens = 96;
    println!("Table-4-style decode throughput (native engine, batch 1)\n");
    for name in ["s0", "s1", "s2"] {
        let cfg = ModelConfig::by_name(name).unwrap();
        let ck = Checkpoint::random(&cfg, 1);
        let model = Transformer::from_checkpoint(&ck).unwrap();
        for bits in [2u32, 4] {
            let q_base = quantize(&model, bits, Processing::baseline());
            let q_incp = quantize(&model, bits, Processing::incoherent());
            let lin_fp = FpLinears { model: &model };
            let lin_base = QuantLinears::from_model(&q_base).unwrap();
            let lin_incp = QuantLinears::from_model(&q_incp).unwrap();
            let t_fp = tok_latency(&model, &lin_fp, tokens);
            let t_b = tok_latency(&model, &lin_base, tokens);
            let t_i = tok_latency(&model, &lin_incp, tokens);
            println!(
                "bench  decode_{name}_q{bits}   fp32 {:8.3}ms  optq-style {:8.3}ms  quip {:8.3}ms  (quip/optq {:.2}x)",
                t_fp * 1e3,
                t_b * 1e3,
                t_i * 1e3,
                t_i / t_b
            );
        }
    }
    println!("\npaper Table 4: QuIP 81ms vs OPTQ 53ms per token (1.53x) — target is the ratio.");

    // PJRT kernel artifact, if built.
    let root = quip::runtime::registry::default_root();
    if let Ok(reg) = quip::runtime::Registry::load(&root) {
        if let Some(spec) = reg.find_kernel(2) {
            let rt = quip::runtime::PjrtRuntime::cpu().unwrap();
            let exe = rt.load(&spec.file).unwrap();
            let mut rng = Rng::new(9);
            let (m, nw, t, n) = (512usize, 32usize, 16usize, 512usize);
            let words: Vec<i32> = (0..m * nw).map(|_| rng.next_u32() as i32).collect();
            let x: Vec<f32> = (0..t * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let inputs = [
                quip::runtime::Input::I32(words, vec![m, nw]),
                quip::runtime::Input::F32(x, vec![t, n]),
            ];
            let lits = quip::runtime::Executable::marshal(&inputs).unwrap();
            let s = quip::util::timer::bench_budget(2, 0.5, || {
                exe.execute_literals(&lits).unwrap()
            });
            quip::util::timer::report("pjrt_kernel_q2_512x512x16", &s);
            let flops = 2.0 * 512.0 * 512.0 * 16.0;
            println!(
                "  kernel effective {:.2} GFLOP/s (interpret-mode CPU; structure target, not TPU wallclock)",
                flops / s.p50 / 1e9
            );
        }
    } else {
        println!("(no artifacts — PJRT kernel bench skipped)");
    }
}
