//! Quantization-time benchmarks: LDLQ vs OPTQ vs greedy per layer size,
//! and the cost breakdown of incoherence processing (Alg 1/2).

use quip::linalg::Mat;
use quip::quant::incoherence::{postprocess, preprocess, Processing};
use quip::quant::{quantize_layer, Method, QuantConfig};
use quip::util::rng::Rng;
use quip::util::testkit::random_hessian;
use quip::util::timer::{bench, report};

fn main() {
    let mut rng = Rng::new(2);
    for n in [128usize, 256, 512] {
        let m = n;
        let w = Mat::from_fn(m, n, |_, _| rng.uniform(-0.1, 0.1));
        let h = random_hessian(&mut rng, n, n / 4, 1e-3);

        for (name, method) in [
            ("ldlq", Method::Ldlq),
            ("optq", Method::Optq),
            ("greedy", Method::Greedy),
            ("near", Method::Nearest),
            ("vq", Method::Vq),
        ] {
            let cfg = QuantConfig {
                bits: 2,
                method,
                processing: Processing::incoherent(),
                greedy_passes: 5,
                ..Default::default()
            };
            let s = bench(1, 3, || quantize_layer(&w, &h, &cfg, 1));
            report(&format!("quantize_{name}_{m}x{n}"), &s);
        }

        // factorization kernels: blocked (auto) vs scalar (§Perf 4)
        {
            let hd = quip::quant::incoherence::damp(&h, 0.01);
            let s_ldl_scalar = bench(1, 3, || quip::linalg::ldl::udu_scalar(&hd, 1e-12));
            report(&format!("udu_scalar_{n}"), &s_ldl_scalar);
            let s_ldl_blocked = bench(1, 3, || quip::linalg::ldl::udu(&hd, 1e-12));
            report(&format!("udu_blocked_{n}"), &s_ldl_blocked);
            let s_chol_scalar = bench(1, 3, || quip::linalg::chol::cholesky_scalar(&hd));
            report(&format!("chol_scalar_{n}"), &s_chol_scalar);
            let s_chol_blocked = bench(1, 3, || quip::linalg::chol::cholesky(&hd));
            report(&format!("chol_blocked_{n}"), &s_chol_blocked);
        }

        // blocked ("lazy batch") LDLQ vs the plain recurrence
        {
            let f = quip::linalg::ldl::udu(&h, 1e-12);
            let u = f.strictly_upper();
            let pre = preprocess(&w, &h, 2, &Processing::incoherent(), 7);
            let s_plain = bench(1, 3, || {
                quip::quant::ldlq::ldlq_with_feedback(
                    &pre.wg, &u, 2, quip::quant::RoundMode::Nearest, 0,
                )
            });
            report(&format!("ldlq_core_plain_{m}x{n}"), &s_plain);
            let s_blk = bench(1, 3, || {
                quip::quant::ldlq::ldlq_with_feedback_blocked(
                    &pre.wg, &u, 2, quip::quant::RoundMode::Nearest, 0, 64,
                )
            });
            report(&format!("ldlq_core_blocked64_{m}x{n}"), &s_blk);
        }

        // incoherence processing alone (pre + post)
        let p = Processing::incoherent();
        let s_pre = bench(1, 3, || preprocess(&w, &h, 2, &p, 7));
        report(&format!("incp_preprocess_{m}x{n}"), &s_pre);
        let pre = preprocess(&w, &h, 2, &p, 7);
        let codes = quip::quant::ldlq::round_matrix(
            &pre.wg,
            2,
            quip::quant::RoundMode::Nearest,
            0,
        );
        let s_post = bench(1, 5, || postprocess(&codes, &pre.post));
        report(&format!("incp_postprocess_{m}x{n}"), &s_post);
    }
}
